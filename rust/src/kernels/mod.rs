//! The three matrix-multiplication kernels of Fig. 2, as instruction-
//! stream builders for the Snitch cluster simulator.
//!
//! * [`fp32`]   — the FP32 baseline: 2-way SIMD `vfmac.s` with SSR
//!               streaming and FREP (4 FLOPs/cycle/core ideal);
//! * [`fp8sw`]  — the FP8-to-FP32 *software* MX baseline: SSR-streamed
//!               packed FP8, per-lane `fcvt` expansion to FP32, FP32
//!               FMAs, explicit block-scale materialization and
//!               application (the paper's 20.9-25× slower kernel);
//! * [`mxfp8`]  — the paper's kernel: one `mxdotp` per 8 elements with
//!               both scales fused, scales reshaped and streamed on the
//!               third SSR, 8-way accumulator unroll under FREP
//!               (16 FLOPs/cycle/core ideal);
//! * [`layout`] — SPM placement (bank-staggered operand regions, L1
//!               capacity checks — reproducing the paper's "FP32 does
//!               not fit into L1 at K=256" footnote) and row-block
//!               multi-core partitioning;
//! * [`plan`]   — the compile-once/execute-many layer: each kernel's
//!               old per-call `stage()` is split into a shape-keyed
//!               [`plan::MmPlan`] (SPM layout + per-core programs +
//!               worst-case cycle bound) and an `execute()` that writes
//!               operands into a reset, long-lived cluster; the
//!               [`plan::PlanCache`] shares plans across identical tile
//!               shapes and quantized B tiles across passes/requests;
//! * [`reference`] — instruction-order-exact analytical references the
//!               simulator's results are compared against *bit for
//!               bit*, plus the FLOP accounting used by Fig. 4.
//!
//! [`run_mm`] below is the *cold* single-call convenience path (plan,
//! quantize, execute once — what the figures and golden tests use);
//! the serving and scale-out layers go through [`plan::run_mm_cached`]
//! and the engine's warm tile loop instead, with bit-identical results.
//!
//! FLOP counting follows Table III's footnote: 1 FLOP = 1 FP multiply
//! or 1 FP add; a matmul is 2·M·N·K FLOPs; scale operations are *not*
//! counted as useful FLOPs (they are overhead the MXFP8 kernel fuses).

pub mod fp8sw;
pub mod fp32;
pub mod layout;
pub mod mxfp8;
pub mod plan;
pub mod reference;

use crate::formats::ElemFormat;
use crate::snitch::cluster::{Cluster, ClusterConfig, PerfCounters};

/// Which kernel to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Fp32,
    Fp8ToFp32,
    Mxfp8,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Fp32 => "FP32",
            KernelKind::Fp8ToFp32 => "FP8-to-FP32",
            KernelKind::Mxfp8 => "MXFP8",
        }
    }
}

/// One matmul problem instance (C[M,N] = A[M,K] · B[K,N]).
#[derive(Clone, Copy, Debug)]
pub struct MmProblem {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub fmt: ElemFormat,
    pub block_size: usize,
}

impl MmProblem {
    /// The Fig. 4 workload: rows/cols fixed at 64, inner dim varies.
    pub fn fig4(k: usize, fmt: ElemFormat) -> Self {
        MmProblem { m: 64, k, n: 64, fmt, block_size: 32 }
    }

    /// Useful FLOPs (2·M·N·K; scale ops not counted, Table III note).
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.n as u64 * self.k as u64
    }
}

/// Result of running one kernel on the simulated cluster.
#[derive(Clone, Debug)]
pub struct MmRun {
    pub kind: KernelKind,
    pub problem: MmProblem,
    pub perf: PerfCounters,
    /// The computed C matrix (row-major M×N).
    pub c: Vec<f32>,
    pub num_cores: usize,
    pub freq_ghz: f64,
}

impl MmRun {
    /// Achieved throughput in GFLOPS at the configured clock.
    pub fn gflops(&self) -> f64 {
        self.problem.flops() as f64 / self.perf.cycles as f64 * self.freq_ghz
    }

    /// Ideal per-kernel throughput (GFLOPS) on this cluster.
    pub fn ideal_gflops(&self) -> f64 {
        let per_core = match self.kind {
            KernelKind::Fp32 => 4.0,       // 2-way SIMD MAC
            KernelKind::Fp8ToFp32 => 4.0,  // bounded by the same FPU MACs
            KernelKind::Mxfp8 => 16.0,     // 8 mul + 8 add per cycle
        };
        per_core * self.num_cores as f64 * self.freq_ghz
    }

    /// Fraction of the kernel's ideal throughput (the paper's 79.7 %).
    pub fn utilization(&self) -> f64 {
        self.gflops() / self.ideal_gflops()
    }
}

/// Run `kind` on an `num_cores`-core cluster and return results +
/// counters. Inputs are FP32 matrices; MX kernels quantize them with
/// the OCP recipe before staging into SPM.
///
/// This is the *cold* path: plan compiled, operands quantized and one
/// execution performed per call, under the plan's per-kernel
/// worst-case cycle bound (guard expiry panics with the kernel name).
/// Warm callers (scale-out, serving) use [`plan::run_mm_cached`] /
/// the engine's tile loop, which are bit-identical.
pub fn run_mm(
    kind: KernelKind,
    problem: MmProblem,
    a: &[f32],
    b: &[f32],
    num_cores: usize,
) -> MmRun {
    let mm_plan = plan::MmPlan::build(plan::PlanKey::new(kind, &problem, num_cores));
    let mut cluster = Cluster::new(ClusterConfig { num_cores, freq_ghz: 1.0 });
    match kind {
        KernelKind::Fp32 => mm_plan.execute(&mut cluster, &plan::MmOperands::Fp32 { a, b }),
        KernelKind::Fp8ToFp32 | KernelKind::Mxfp8 => {
            let (qa, qb) = mm_plan.quantize(a, b);
            mm_plan.execute(&mut cluster, &plan::MmOperands::Mx { qa: &qa, qb: &qb })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift;

    #[test]
    fn flop_accounting() {
        let p = MmProblem::fig4(128, ElemFormat::E4M3);
        assert_eq!(p.flops(), 2 * 64 * 64 * 128);
    }

    /// Run `kinds` on the simulated cluster and assert bit-agreement
    /// with each kernel's instruction-order-exact reference (NaN
    /// compares as NaN; everything else bit-for-bit).
    fn assert_kernels_agree(
        what: &str,
        p: MmProblem,
        a: &[f32],
        b: &[f32],
        cores: usize,
        kinds: &[KernelKind],
    ) {
        for &kind in kinds {
            let want = match kind {
                KernelKind::Fp32 => reference::fp32_hw_ref(&p, a, b),
                KernelKind::Fp8ToFp32 => reference::fp8sw_hw_ref(&p, a, b),
                KernelKind::Mxfp8 => reference::mxfp8_hw_ref(&p, a, b),
            };
            let run = run_mm(kind, p, a, b, cores);
            assert_eq!(run.c.len(), want.len());
            for (i, (&got, &w)) in run.c.iter().zip(&want).enumerate() {
                assert!(
                    got.to_bits() == w.to_bits() || (got.is_nan() && w.is_nan()),
                    "{what} / {}: C[{i}] = {got:?} (bits {:08x}), want {w:?} ({:08x})",
                    kind.name(),
                    got.to_bits(),
                    w.to_bits()
                );
            }
        }
    }

    const ALL_KINDS: [KernelKind; 3] =
        [KernelKind::Fp32, KernelKind::Fp8ToFp32, KernelKind::Mxfp8];

    #[test]
    fn all_three_kernels_agree_with_their_references() {
        let mut rng = XorShift::new(0xC0DE);
        let p = MmProblem { m: 16, k: 64, n: 16, fmt: ElemFormat::E4M3, block_size: 32 };
        let a = rng.normal_vec(p.m * p.k, 1.0);
        let b = rng.normal_vec(p.k * p.n, 1.0);
        assert_kernels_agree("e4m3", p, &a, &b, 2, &ALL_KINDS);
    }

    #[test]
    fn all_three_kernels_agree_on_e5m2() {
        let mut rng = XorShift::new(0xE5A2);
        let p = MmProblem { m: 16, k: 64, n: 16, fmt: ElemFormat::E5M2, block_size: 32 };
        let a = rng.normal_vec(p.m * p.k, 1.0);
        let b = rng.normal_vec(p.k * p.n, 1.0);
        assert_kernels_agree("e5m2", p, &a, &b, 2, &ALL_KINDS);
    }

    #[test]
    fn kernels_agree_on_non_default_block_sizes() {
        // "the block size remains configurable in software": the MXFP8
        // kernel's ft2 middle bound adapts; FP32 ignores the block size
        // entirely. The FP8-to-FP32 software baseline is written for
        // the spec's block 32 only (its plan asserts so) and is
        // exercised at 32 by the tests above.
        for bs in [16usize, 64] {
            let p = MmProblem { m: 8, k: 128, n: 16, fmt: ElemFormat::E4M3, block_size: bs };
            let mut rng = XorShift::new(0xB5 + bs as u64);
            let a = rng.normal_vec(p.m * p.k, 1.0);
            let b = rng.normal_vec(p.k * p.n, 1.0);
            assert_kernels_agree(
                &format!("bs={bs}"),
                p,
                &a,
                &b,
                2,
                &[KernelKind::Fp32, KernelKind::Mxfp8],
            );
        }
    }

    #[test]
    fn kernels_agree_on_nan_and_inf_operands() {
        // NaN poisons, E5M2 infinities propagate (E4M3 has no Inf
        // encoding: the OCP recipe saturates ±Inf to ±max-normal).
        // The simulator executes these through the architectural
        // MxDotpUnit; the references must agree element for element.
        for fmt in [ElemFormat::E4M3, ElemFormat::E5M2] {
            let p = MmProblem { m: 8, k: 64, n: 16, fmt, block_size: 32 };
            let mut rng = XorShift::new(0x7A7);
            let mut a = rng.normal_vec(p.m * p.k, 1.0);
            let mut b = rng.normal_vec(p.k * p.n, 1.0);
            a[3] = f32::NAN; // row 0: NaN poisons every C[0][*]
            a[p.k + 10] = f32::INFINITY; // row 1: ±Inf propagation
            a[2 * p.k + 5] = f32::NEG_INFINITY;
            b[4 * p.n + 7] = f32::NAN; // column 7 via k=4
            b[9 * p.n + 3] = f32::INFINITY;
            assert_kernels_agree(&format!("{fmt} specials"), p, &a, &b, 2, &ALL_KINDS);
        }
    }

    #[test]
    fn kernels_agree_on_subnormal_heavy_blocks() {
        // Whole FP32-subnormal blocks force the OCP shared exponent to
        // its EMIN clamp and exercise the quantizer's and datapath's
        // denormal paths.
        for fmt in [ElemFormat::E4M3, ElemFormat::E5M2] {
            let p = MmProblem { m: 8, k: 64, n: 16, fmt, block_size: 32 };
            let mut rng = XorShift::new(0x5AB);
            let mut a = rng.normal_vec(p.m * p.k, 1.0);
            let mut b = rng.normal_vec(p.k * p.n, 1.0);
            // first K-block of every A row: subnormal magnitudes
            for (m, row) in (0..p.m).map(|m| (m, m * p.k)) {
                for k in 0..p.block_size {
                    let tiny = f32::from_bits(1 + (m * 97 + k * 13) as u32 % 0x7F_FFFF);
                    a[row + k] = if k % 2 == 0 { tiny } else { -tiny };
                }
            }
            // one B block per column mixes subnormals with normals
            for n in 0..p.n {
                for k in 32..48 {
                    b[k * p.n + n] = f32::from_bits(((n * 31 + k) as u32 % 0xFFFF) + 1);
                }
            }
            assert_kernels_agree(&format!("{fmt} subnormals"), p, &a, &b, 2, &ALL_KINDS);
        }
    }

    #[test]
    fn mxfp8_beats_fp32_beats_fp8sw() {
        let mut rng = XorShift::new(0x5EED);
        let p = MmProblem::fig4(64, ElemFormat::E4M3);
        let a = rng.normal_vec(p.m * p.k, 1.0);
        let b = rng.normal_vec(p.k * p.n, 1.0);
        let mx = run_mm(KernelKind::Mxfp8, p, &a, &b, 8);
        let f32k = run_mm(KernelKind::Fp32, p, &a, &b, 8);
        let sw = run_mm(KernelKind::Fp8ToFp32, p, &a, &b, 8);
        assert!(mx.gflops() > f32k.gflops() * 2.0, "mx {} vs fp32 {}", mx.gflops(), f32k.gflops());
        assert!(f32k.gflops() > sw.gflops() * 2.0, "fp32 {} vs sw {}", f32k.gflops(), sw.gflops());
    }
}
