//! The typed metrics registry behind `OBS_metrics.json`.
//!
//! Three metric kinds, all keyed by a flat string name and stored in
//! `BTreeMap`s so every rendering is canonically ordered:
//!
//! * **counters** — monotonically accumulated `u64` event counts
//!   (requests served, reloads paid, simulated cycles);
//! * **gauges** — point-in-time `f64` levels (utilization, maximum
//!   queue depth);
//! * **histograms** — `u64` sample sets summarized with the same
//!   nearest-rank rule as the serving metrics
//!   ([`crate::serve::metrics::percentile_ticks`]), so an exported
//!   p99 is always a value some sample actually took.
//!
//! Every value recorded here is derived from **simulated** state, so
//! [`Registry::render_json`] is a pure function of the run and two
//! identical runs export byte-identical files — the property the
//! determinism CI job checks. Host wall-clock numbers are quarantined
//! in an optional `host_profile` block whose keys all carry the
//! `host_` prefix that `tools/check_determinism.py` strips
//! (DESIGN.md §14).

use super::hostprof::HostProfile;
use crate::serve::metrics::percentile_ticks;
use std::collections::BTreeMap;

/// Render a finite `f64` as a JSON number (shortest round-trip form);
/// non-finite values render as `null`.
pub(crate) fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// Escape a string for embedding in a JSON document (quotes included).
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A typed, deterministically ordered metrics registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Vec<u64>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add `v` to the named counter (creating it at 0).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Current value of a counter (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set the named gauge to `v` (last write wins).
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Current value of a gauge, if it was ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one sample into the named histogram.
    pub fn hist_record(&mut self, name: &str, v: u64) {
        self.hists.entry(name.to_string()).or_default().push(v);
    }

    /// Nearest-rank summary of a histogram:
    /// `(count, min, p50, p95, p99, max, sum)`; all zero when empty.
    pub fn hist_summary(&self, name: &str) -> (usize, u64, u64, u64, u64, u64, u64) {
        let Some(samples) = self.hists.get(name) else {
            return (0, 0, 0, 0, 0, 0, 0);
        };
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        let count = sorted.len();
        if count == 0 {
            return (0, 0, 0, 0, 0, 0, 0);
        }
        (
            count,
            sorted[0],
            percentile_ticks(&sorted, 0.50),
            percentile_ticks(&sorted, 0.95),
            percentile_ticks(&sorted, 0.99),
            *sorted.last().unwrap(),
            sorted.iter().sum(),
        )
    }

    /// Absorb another registry: counters add, gauges overwrite,
    /// histogram samples append.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.hists {
            self.hists.entry(k.clone()).or_default().extend(v);
        }
    }

    /// Render the registry as a deterministic pretty-printed JSON
    /// object: `BTreeMap` key order, shortest-round-trip floats, no
    /// host state — two identical simulated runs produce byte-equal
    /// output.
    pub fn render_json(&self) -> String {
        self.render_json_with_host(None)
    }

    /// [`Registry::render_json`] plus an optional `host_profile` block
    /// of wall-clock measurements. Every key in the block carries the
    /// `host_` prefix: the determinism checker strips such keys, so
    /// adding host numbers never breaks twice-run bit-identity.
    pub fn render_json_with_host(&self, host: Option<&HostProfile>) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"counters\": {");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!("    {}: {}", json_string(k), v));
        }
        out.push_str(if self.counters.is_empty() { "},\n" } else { "\n  },\n" });
        out.push_str("  \"gauges\": {");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!("    {}: {}", json_string(k), json_f64(*v)));
        }
        out.push_str(if self.gauges.is_empty() { "},\n" } else { "\n  },\n" });
        out.push_str("  \"histograms\": {");
        for (i, k) in self.hists.keys().enumerate() {
            let (count, min, p50, p95, p99, max, sum) = self.hist_summary(k);
            let mean = if count > 0 { sum as f64 / count as f64 } else { 0.0 };
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!(
                "    {}: {{ \"count\": {count}, \"min\": {min}, \"p50\": {p50}, \
                 \"p95\": {p95}, \"p99\": {p99}, \"max\": {max}, \"sum\": {sum}, \
                 \"mean\": {} }}",
                json_string(k),
                json_f64(mean)
            ));
        }
        out.push_str(if self.hists.is_empty() { "}" } else { "\n  }" });
        if let Some(h) = host {
            out.push_str(",\n  \"host_profile\": {\n");
            out.push_str(&format!(
                "    \"host_sim_wall_ms\": {},\n",
                json_f64(h.sim_wall_ms())
            ));
            out.push_str(&format!(
                "    \"host_sim_cycles_per_host_us\": {},\n",
                json_f64(h.sim_cycles_per_host_us())
            ));
            out.push_str(&format!("    \"host_sim_runs\": {},\n", h.sim_runs));
            out.push_str(&format!("    \"host_plan_builds\": {},\n", h.plan_builds));
            out.push_str(&format!(
                "    \"host_plan_build_ms\": {}\n",
                json_f64(h.plan_build_nanos as f64 / 1e6)
            ));
            out.push_str("  }");
        }
        out.push_str("\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_round_trip() {
        let mut r = Registry::new();
        r.counter_add("serve.served", 3);
        r.counter_add("serve.served", 2);
        r.gauge_set("util", 0.5);
        for v in [10, 20, 30, 40] {
            r.hist_record("lat", v);
        }
        assert_eq!(r.counter("serve.served"), 5);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("util"), Some(0.5));
        let (count, min, p50, _, _, max, sum) = r.hist_summary("lat");
        assert_eq!((count, min, max, sum), (4, 10, 40, 100));
        assert_eq!(p50, 30); // matches serve::metrics doctest ranking
        assert_eq!(r.hist_summary("missing").0, 0);
    }

    #[test]
    fn render_is_deterministic_and_ordered() {
        let build = || {
            let mut r = Registry::new();
            r.counter_add("b", 2);
            r.counter_add("a", 1);
            r.gauge_set("z", 1.25);
            r.hist_record("h", 7);
            r
        };
        let j1 = build().render_json();
        let j2 = build().render_json();
        assert_eq!(j1, j2, "identical registries must render byte-identically");
        // BTreeMap ordering: "a" before "b" regardless of insert order
        assert!(j1.find("\"a\"").unwrap() < j1.find("\"b\"").unwrap());
        assert!(j1.contains("\"p99\": 7"));
    }

    #[test]
    fn merge_adds_counters_and_appends_samples() {
        let mut a = Registry::new();
        a.counter_add("c", 1);
        a.hist_record("h", 1);
        let mut b = Registry::new();
        b.counter_add("c", 2);
        b.hist_record("h", 9);
        b.gauge_set("g", 4.0);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        assert_eq!(a.hist_summary("h").0, 2);
        assert_eq!(a.gauge("g"), Some(4.0));
    }

    #[test]
    fn json_helpers_escape_and_render() {
        assert_eq!(json_string("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_f64(0.5), "0.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }

    #[test]
    fn empty_registry_renders_valid_json() {
        let j = Registry::new().render_json();
        assert!(j.contains("\"counters\": {}"));
        assert!(j.contains("\"gauges\": {}"));
        assert!(j.contains("\"histograms\": {}"));
    }
}
