//! Chrome/Perfetto trace-event JSON export.
//!
//! Renders a [`TraceSink`] as a plain trace-event array loadable by
//! <https://ui.perfetto.dev> (or `chrome://tracing`): `M` metadata
//! events name the process/track lanes, every span becomes a complete
//! `X` event, and counter samples become `C` events.
//!
//! Determinism: timestamps are simulated nanoseconds rendered as exact
//! microsecond decimals (`ts = ns/1000 + "." + ns%1000`, pure integer
//! arithmetic — no float formatting), events are emitted in a total
//! order (`(pid, tid, ts, longest-first, name)` so enclosing spans
//! precede their children at equal start), and all map iteration is
//! over `BTreeMap`s. Two sinks recorded from identical runs therefore
//! render byte-identically, which is what lets the determinism CI job
//! diff trace artifacts like any other `OBS_*` file.
//! `tools/check_trace.py` validates the schema (well-formed array,
//! monotonic `ts` per track, complete `X` events) in CI.

use super::metrics::{json_f64, json_string};
use super::span::{Span, TraceSink};

/// Exact microseconds-with-nanosecond-fraction rendering of a
/// simulated-ns timestamp (the trace-event `ts`/`dur` unit is µs).
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// The sink's spans in the exporter's canonical event order:
/// `(pid, tid, ts, longer-duration-first, name)`. Sorting longest
/// first at equal start keeps enclosing spans ahead of the children
/// they contain, which nested-slice viewers require.
pub fn sorted_spans(sink: &TraceSink) -> Vec<&Span> {
    let mut spans: Vec<&Span> = sink.spans().iter().collect();
    spans.sort_by(|a, b| {
        (a.pid, a.tid, a.ts_ns)
            .cmp(&(b.pid, b.tid, b.ts_ns))
            .then(b.dur_ns.cmp(&a.dur_ns))
            .then(a.name.cmp(&b.name))
    });
    spans
}

/// Render the sink as a Chrome trace-event JSON array (one event per
/// line). Pure function of the sink: byte-identical for equal sinks.
pub fn render(sink: &TraceSink) -> String {
    let mut events: Vec<String> = Vec::new();
    for (pid, name) in sink.processes() {
        events.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
             \"args\":{{\"name\":{}}}}}",
            json_string(name)
        ));
    }
    for ((pid, tid), name) in sink.threads() {
        events.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":{}}}}}",
            json_string(name)
        ));
    }
    for s in sorted_spans(sink) {
        let mut args = String::new();
        for (i, (k, v)) in s.args.iter().enumerate() {
            if i > 0 {
                args.push(',');
            }
            args.push_str(&format!("{}:{}", json_string(k), json_string(v)));
        }
        events.push(format!(
            "{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\
             \"cat\":{},\"name\":{},\"args\":{{{args}}}}}",
            s.pid,
            s.tid,
            us(s.ts_ns),
            us(s.dur_ns),
            json_string(s.cat),
            json_string(&s.name),
        ));
    }
    let mut counters: Vec<_> = sink.counters().iter().collect();
    counters.sort_by(|a, b| {
        (a.pid, &a.name, a.ts_ns)
            .cmp(&(b.pid, &b.name, b.ts_ns))
            .then(a.value.total_cmp(&b.value))
    });
    for c in counters {
        events.push(format!(
            "{{\"ph\":\"C\",\"pid\":{},\"tid\":0,\"ts\":{},\"name\":{},\
             \"args\":{{\"value\":{}}}}}",
            c.pid,
            us(c.ts_ns),
            json_string(&c.name),
            json_f64(c.value),
        ));
    }
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(if i == 0 { "" } else { ",\n" });
        out.push_str(e);
    }
    out.push_str("\n]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::CounterSample;

    fn sink() -> TraceSink {
        let mut s = TraceSink::new();
        s.name_process(1, "machine");
        s.name_thread(1, 0, "fabric 0");
        s.record(Span {
            pid: 1,
            tid: 0,
            name: "child".into(),
            cat: "t",
            ts_ns: 1500,
            dur_ns: 500,
            args: vec![("fmt", "e4m3".into())],
        });
        s.record(Span {
            pid: 1,
            tid: 0,
            name: "parent".into(),
            cat: "t",
            ts_ns: 1500,
            dur_ns: 2500,
            args: Vec::new(),
        });
        s.record_counter(CounterSample { pid: 1, name: "depth".into(), ts_ns: 0, value: 2.0 });
        s
    }

    #[test]
    fn timestamps_are_exact_microsecond_decimals() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1500), "1.500");
        assert_eq!(us(1_234_567), "1234.567");
    }

    #[test]
    fn render_orders_parents_first_and_is_deterministic() {
        let j1 = render(&sink());
        let j2 = render(&sink());
        assert_eq!(j1, j2);
        assert!(j1.starts_with("[\n"));
        assert!(j1.ends_with("\n]\n"));
        // the longer (enclosing) span precedes the child at equal ts
        assert!(j1.find("\"parent\"").unwrap() < j1.find("\"child\"").unwrap());
        assert!(j1.contains("\"ts\":1.500"));
        assert!(j1.contains("\"dur\":2.500"));
        assert!(j1.contains("\"process_name\""));
        assert!(j1.contains("\"thread_name\""));
        assert!(j1.contains("\"ph\":\"C\""));
        assert!(j1.contains("\"fmt\":\"e4m3\""));
    }

    #[test]
    fn sorted_spans_are_monotonic_per_track() {
        let s = sink();
        let sorted = sorted_spans(&s);
        for w in sorted.windows(2) {
            if (w[0].pid, w[0].tid) == (w[1].pid, w[1].tid) {
                assert!(w[0].ts_ns <= w[1].ts_ns);
            }
        }
    }
}
