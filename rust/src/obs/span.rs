//! Sim-time spans and the `TraceSink` they accumulate in.
//!
//! A [`Span`] is one closed interval of **simulated** time on one
//! track: its timestamps are cluster cycles (1 cycle = 1 ns at the
//! paper's 1 GHz operating point; 1 scheduler tick =
//! [`crate::serve::CYCLES_PER_TICK`] cycles). Host wall-clock never
//! appears in a span — that is the determinism rule that keeps traces
//! bit-for-bit reproducible (DESIGN.md §14); host-side profiling lives
//! in [`crate::obs::hostprof`] instead.
//!
//! A [`TraceSink`] is a plain append-only buffer: recording a span is
//! a `Vec::push`, with no locking and no I/O. Worker threads that emit
//! spans each own a private sink (the scale-out pool's per-worker
//! buffers) and the owner merges them afterwards with
//! [`TraceSink::merge`] — the same join-then-combine discipline the
//! pool already uses for shard outputs, so tracing adds no
//! synchronization to the simulated path. When tracing is disabled no
//! sink exists at all (callers pass `None`); the hot paths never
//! allocate on its behalf.

use std::collections::BTreeMap;

/// One span of simulated time on one trace track.
///
/// `pid`/`tid` follow the Chrome trace-event convention: `pid` groups
/// related tracks into one named process lane (see the `PID_*`
/// constants in [`crate::obs`]) and `tid` is the track within it
/// (a fabric, a cluster, a core, ...).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// Process lane (top-level grouping in the viewer).
    pub pid: u32,
    /// Track within the process lane.
    pub tid: u32,
    /// Display name of the span.
    pub name: String,
    /// Category tag (filterable in the viewer), e.g. `"serve.batch"`.
    pub cat: &'static str,
    /// Start of the span in simulated nanoseconds (= cycles at 1 GHz).
    pub ts_ns: u64,
    /// Duration in simulated nanoseconds; 0 renders as an instant.
    pub dur_ns: u64,
    /// Ordered key/value annotations shown in the viewer's args pane.
    pub args: Vec<(&'static str, String)>,
}

/// One sample of a counter track (rendered as a Chrome `ph:"C"`
/// event): the counter's value from this simulated instant onward.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterSample {
    /// Process lane the counter belongs to.
    pub pid: u32,
    /// Counter name (one plot per name in the viewer).
    pub name: String,
    /// Sample time in simulated nanoseconds.
    pub ts_ns: u64,
    /// Sampled value.
    pub value: f64,
}

/// Append-only buffer of spans, counter samples, and track names.
///
/// Everything a sink holds is a pure function of simulated state, so
/// two sinks recorded from identical runs are `==` and render to
/// byte-identical JSON ([`crate::obs::perfetto::render`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSink {
    spans: Vec<Span>,
    counters: Vec<CounterSample>,
    processes: BTreeMap<u32, String>,
    threads: BTreeMap<(u32, u32), String>,
}

impl TraceSink {
    /// An empty sink.
    pub fn new() -> Self {
        TraceSink::default()
    }

    /// Append one span (no ordering requirement; the exporter sorts).
    pub fn record(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Append one counter sample.
    pub fn record_counter(&mut self, sample: CounterSample) {
        self.counters.push(sample);
    }

    /// Name a process lane (viewer metadata; last write wins).
    pub fn name_process(&mut self, pid: u32, name: impl Into<String>) {
        self.processes.insert(pid, name.into());
    }

    /// Name a track within a process lane (last write wins).
    pub fn name_thread(&mut self, pid: u32, tid: u32, name: impl Into<String>) {
        self.threads.insert((pid, tid), name.into());
    }

    /// Absorb another sink (a worker's private buffer) into this one.
    /// Spans keep their recorded order within each source; track names
    /// from `other` win on collision.
    pub fn merge(&mut self, other: TraceSink) {
        self.spans.extend(other.spans);
        self.counters.extend(other.counters);
        self.processes.extend(other.processes);
        self.threads.extend(other.threads);
    }

    /// The recorded spans, in recording order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// The recorded counter samples, in recording order.
    pub fn counters(&self) -> &[CounterSample] {
        &self.counters
    }

    /// Named process lanes (sorted by pid).
    pub fn processes(&self) -> &BTreeMap<u32, String> {
        &self.processes
    }

    /// Named tracks (sorted by (pid, tid)).
    pub fn threads(&self) -> &BTreeMap<(u32, u32), String> {
        &self.threads
    }

    /// Number of recorded spans.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// True when no span has been recorded.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Sum of span durations (ns) on one track — the reconciliation
    /// primitive: per-fabric serve spans must sum to the scheduler's
    /// busy-tick accounting (asserted in `tests/obs.rs`).
    pub fn track_total_ns(&self, pid: u32, tid: u32) -> u64 {
        self.spans
            .iter()
            .filter(|s| s.pid == pid && s.tid == tid)
            .map(|s| s.dur_ns)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(pid: u32, tid: u32, ts: u64, dur: u64) -> Span {
        Span {
            pid,
            tid,
            name: format!("s{ts}"),
            cat: "test",
            ts_ns: ts,
            dur_ns: dur,
            args: Vec::new(),
        }
    }

    #[test]
    fn record_merge_and_track_totals() {
        let mut a = TraceSink::new();
        a.name_process(1, "machine");
        a.name_thread(1, 0, "fabric 0");
        a.record(span(1, 0, 0, 10));
        a.record(span(1, 1, 5, 7));
        let mut b = TraceSink::new();
        b.record(span(1, 0, 20, 3));
        b.name_thread(1, 1, "fabric 1");
        a.merge(b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.track_total_ns(1, 0), 13);
        assert_eq!(a.track_total_ns(1, 1), 7);
        assert_eq!(a.track_total_ns(2, 0), 0);
        assert_eq!(a.processes()[&1], "machine");
        assert_eq!(a.threads()[&(1, 1)], "fabric 1");
    }

    #[test]
    fn identical_recordings_compare_equal() {
        let build = || {
            let mut s = TraceSink::new();
            s.name_process(3, "model");
            s.record(span(3, 0, 4, 4));
            s.record_counter(CounterSample {
                pid: 3,
                name: "queue depth".into(),
                ts_ns: 4,
                value: 2.0,
            });
            s
        };
        assert_eq!(build(), build());
    }
}
