//! Host-side wall-clock profiling of the simulator itself.
//!
//! Everything else in `obs` is stamped in *simulated* time; this
//! module is the one sanctioned home for **host** wall-clock. It
//! answers the ROADMAP's "simulator hot-loop speed" question — how
//! many simulated cycles does a host microsecond buy? — by timing the
//! two host-dominant paths:
//!
//! * the snitch decode/execute hot loop
//!   ([`crate::snitch::Cluster::run_checked`] wraps every simulated
//!   run with one [`std::time::Instant`] pair), and
//! * plan compilation ([`crate::kernels::PlanCache`] times each
//!   [`crate::kernels::MmPlan`] build).
//!
//! The counters are process-global relaxed atomics: two `fetch_add`s
//! per multi-thousand-cycle cluster run, cheap enough to stay
//! always-on. Their values are **never** fed back into simulation and
//! never appear in deterministic artifacts except under `host_`-
//! prefixed keys (which `tools/check_determinism.py` strips), so the
//! bit-reproducibility story is untouched. `benches/hotpath.rs`
//! surfaces the headline ratio as `sim_cycles_per_host_us` in
//! `BENCH_hotpath.json`, min-bounded by the bench-regression gate.

use std::sync::atomic::{AtomicU64, Ordering};

static SIM_WALL_NANOS: AtomicU64 = AtomicU64::new(0);
static SIM_CYCLES: AtomicU64 = AtomicU64::new(0);
static SIM_RUNS: AtomicU64 = AtomicU64::new(0);
static PLAN_BUILD_NANOS: AtomicU64 = AtomicU64::new(0);
static PLAN_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Record one timed simulator run: `nanos` of host wall-clock spent
/// advancing `cycles` simulated cycles.
pub fn record_sim(nanos: u64, cycles: u64) {
    SIM_WALL_NANOS.fetch_add(nanos, Ordering::Relaxed);
    SIM_CYCLES.fetch_add(cycles, Ordering::Relaxed);
    SIM_RUNS.fetch_add(1, Ordering::Relaxed);
}

/// Record one timed plan compilation.
pub fn record_plan_build(nanos: u64) {
    PLAN_BUILD_NANOS.fetch_add(nanos, Ordering::Relaxed);
    PLAN_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// Zero every counter — call at the start of a measurement window
/// (benches do; the CLI reports whole-process totals).
pub fn reset() {
    SIM_WALL_NANOS.store(0, Ordering::Relaxed);
    SIM_CYCLES.store(0, Ordering::Relaxed);
    SIM_RUNS.store(0, Ordering::Relaxed);
    PLAN_BUILD_NANOS.store(0, Ordering::Relaxed);
    PLAN_BUILDS.store(0, Ordering::Relaxed);
}

/// A point-in-time copy of the profiling counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostProfile {
    /// Host nanoseconds spent inside timed simulator runs.
    pub sim_wall_nanos: u64,
    /// Simulated cycles advanced by those runs.
    pub sim_cycles: u64,
    /// Number of timed simulator runs.
    pub sim_runs: u64,
    /// Host nanoseconds spent compiling `MmPlan`s.
    pub plan_build_nanos: u64,
    /// Number of plan compilations.
    pub plan_builds: u64,
}

impl HostProfile {
    /// Host milliseconds spent simulating (`sim_wall_ms` in
    /// `BENCH_hotpath.json`).
    pub fn sim_wall_ms(&self) -> f64 {
        self.sim_wall_nanos as f64 / 1e6
    }

    /// Simulator speed: simulated cycles per host microsecond (the
    /// gated `sim_cycles_per_host_us` metric). 0 when nothing ran.
    pub fn sim_cycles_per_host_us(&self) -> f64 {
        if self.sim_wall_nanos == 0 {
            return 0.0;
        }
        self.sim_cycles as f64 * 1e3 / self.sim_wall_nanos as f64
    }
}

/// Snapshot the current counter values.
pub fn snapshot() -> HostProfile {
    HostProfile {
        sim_wall_nanos: SIM_WALL_NANOS.load(Ordering::Relaxed),
        sim_cycles: SIM_CYCLES.load(Ordering::Relaxed),
        sim_runs: SIM_RUNS.load(Ordering::Relaxed),
        plan_build_nanos: PLAN_BUILD_NANOS.load(Ordering::Relaxed),
        plan_builds: PLAN_BUILDS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_are_well_defined() {
        // Pure arithmetic on a local snapshot: the global counters are
        // shared with concurrently running tests, so assertions on
        // them would race — the integration suite covers accumulation.
        let p = HostProfile {
            sim_wall_nanos: 2_000_000,
            sim_cycles: 10_000,
            sim_runs: 2,
            plan_build_nanos: 0,
            plan_builds: 0,
        };
        assert!((p.sim_wall_ms() - 2.0).abs() < 1e-12);
        assert!((p.sim_cycles_per_host_us() - 5.0).abs() < 1e-12);
        assert_eq!(HostProfile::default().sim_cycles_per_host_us(), 0.0);
    }

    #[test]
    fn recording_accumulates_monotonically() {
        let before = snapshot();
        record_sim(1_000, 500);
        record_plan_build(250);
        let after = snapshot();
        assert!(after.sim_wall_nanos >= before.sim_wall_nanos + 1_000);
        assert!(after.sim_cycles >= before.sim_cycles + 500);
        assert!(after.sim_runs >= before.sim_runs + 1);
        assert!(after.plan_builds >= before.plan_builds + 1);
    }
}
