//! Host-side wall-clock profiling of the simulator itself.
//!
//! Everything else in `obs` is stamped in *simulated* time; this
//! module is the one sanctioned home for **host** wall-clock. It
//! answers the ROADMAP's "simulator hot-loop speed" question — how
//! many simulated cycles does a host microsecond buy? — by timing the
//! host-dominant phases separately, so `BENCH_hotpath.json` can
//! attribute host wall to simulator phases instead of one global
//! ratio:
//!
//! * **decode/execute** — the snitch hot loop
//!   ([`crate::snitch::Cluster::run_checked`] wraps every simulated
//!   run with one [`std::time::Instant`] pair) plus the FREP
//!   fast-forward hit counter (fast cycles retired by the slim path);
//! * **plan** — plan compilation ([`crate::kernels::PlanCache`] times
//!   each [`crate::kernels::MmPlan`] build);
//! * **quantize** — MX operand quantization on the cached-pass path;
//! * **replay** — layer-run cache hits: simulated cycles *delivered*
//!   from the memoized layer cache without re-entering the cycle loop.
//!
//! The counters are process-global relaxed atomics: a few `fetch_add`s
//! per multi-thousand-cycle cluster run, cheap enough to stay
//! always-on. Their values are **never** fed back into simulation and
//! never appear in deterministic artifacts except under `host_`-
//! prefixed keys (which `tools/check_determinism.py` strips), so the
//! bit-reproducibility story is untouched. `benches/hotpath.rs`
//! surfaces the headline ratio as `sim_cycles_per_host_us` in
//! `BENCH_hotpath.json`, min-bounded by the bench-regression gate.

use std::sync::atomic::{AtomicU64, Ordering};

static SIM_WALL_NANOS: AtomicU64 = AtomicU64::new(0);
static SIM_CYCLES: AtomicU64 = AtomicU64::new(0);
static SIM_RUNS: AtomicU64 = AtomicU64::new(0);
static PLAN_BUILD_NANOS: AtomicU64 = AtomicU64::new(0);
static PLAN_BUILDS: AtomicU64 = AtomicU64::new(0);
static FF_CYCLES: AtomicU64 = AtomicU64::new(0);
static QUANTIZE_NANOS: AtomicU64 = AtomicU64::new(0);
static QUANTIZES: AtomicU64 = AtomicU64::new(0);
static REPLAY_NANOS: AtomicU64 = AtomicU64::new(0);
static REPLAY_CYCLES: AtomicU64 = AtomicU64::new(0);
static REPLAY_RUNS: AtomicU64 = AtomicU64::new(0);

/// Record one timed simulator run: `nanos` of host wall-clock spent
/// advancing `cycles` simulated cycles.
pub fn record_sim(nanos: u64, cycles: u64) {
    SIM_WALL_NANOS.fetch_add(nanos, Ordering::Relaxed);
    SIM_CYCLES.fetch_add(cycles, Ordering::Relaxed);
    SIM_RUNS.fetch_add(1, Ordering::Relaxed);
}

/// Record one timed plan compilation.
pub fn record_plan_build(nanos: u64) {
    PLAN_BUILD_NANOS.fetch_add(nanos, Ordering::Relaxed);
    PLAN_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// Record how many of a run's cycles were retired by the FREP
/// fast-forward path (a subset of that run's `record_sim` cycles).
pub fn record_frep_ff(cycles: u64) {
    FF_CYCLES.fetch_add(cycles, Ordering::Relaxed);
}

/// Record one timed MX quantization (operand prep before simulation).
pub fn record_quantize(nanos: u64) {
    QUANTIZE_NANOS.fetch_add(nanos, Ordering::Relaxed);
    QUANTIZES.fetch_add(1, Ordering::Relaxed);
}

/// Record one layer-run cache hit: `cycles` of simulated work
/// delivered in `nanos` of host wall without entering the cycle loop.
pub fn record_replay(nanos: u64, cycles: u64) {
    REPLAY_NANOS.fetch_add(nanos, Ordering::Relaxed);
    REPLAY_CYCLES.fetch_add(cycles, Ordering::Relaxed);
    REPLAY_RUNS.fetch_add(1, Ordering::Relaxed);
}

/// Zero every counter — call at the start of a measurement window
/// (benches do; the CLI reports whole-process totals).
pub fn reset() {
    SIM_WALL_NANOS.store(0, Ordering::Relaxed);
    SIM_CYCLES.store(0, Ordering::Relaxed);
    SIM_RUNS.store(0, Ordering::Relaxed);
    PLAN_BUILD_NANOS.store(0, Ordering::Relaxed);
    PLAN_BUILDS.store(0, Ordering::Relaxed);
    FF_CYCLES.store(0, Ordering::Relaxed);
    QUANTIZE_NANOS.store(0, Ordering::Relaxed);
    QUANTIZES.store(0, Ordering::Relaxed);
    REPLAY_NANOS.store(0, Ordering::Relaxed);
    REPLAY_CYCLES.store(0, Ordering::Relaxed);
    REPLAY_RUNS.store(0, Ordering::Relaxed);
}

/// A point-in-time copy of the profiling counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostProfile {
    /// Host nanoseconds spent inside timed simulator runs.
    pub sim_wall_nanos: u64,
    /// Simulated cycles advanced by those runs.
    pub sim_cycles: u64,
    /// Number of timed simulator runs.
    pub sim_runs: u64,
    /// Host nanoseconds spent compiling `MmPlan`s.
    pub plan_build_nanos: u64,
    /// Number of plan compilations.
    pub plan_builds: u64,
    /// Simulated cycles retired by the FREP fast-forward path (a
    /// subset of `sim_cycles`).
    pub ff_cycles: u64,
    /// Host nanoseconds spent quantizing MX operands.
    pub quantize_nanos: u64,
    /// Number of timed quantizations.
    pub quantizes: u64,
    /// Host nanoseconds spent serving layer-run cache hits.
    pub replay_nanos: u64,
    /// Simulated cycles delivered from the layer-run cache (disjoint
    /// from `sim_cycles` — these runs never entered the cycle loop).
    pub replay_cycles: u64,
    /// Number of layer-run cache hits.
    pub replay_runs: u64,
}

impl HostProfile {
    /// Host milliseconds spent simulating (`sim_wall_ms` in
    /// `BENCH_hotpath.json`).
    pub fn sim_wall_ms(&self) -> f64 {
        self.sim_wall_nanos as f64 / 1e6
    }

    /// Simulator speed: simulated cycles per host microsecond (the
    /// gated `sim_cycles_per_host_us` metric). 0 when nothing ran.
    pub fn sim_cycles_per_host_us(&self) -> f64 {
        if self.sim_wall_nanos == 0 {
            return 0.0;
        }
        self.sim_cycles as f64 * 1e3 / self.sim_wall_nanos as f64
    }

    /// Fraction of simulated cycles retired by the FREP fast-forward
    /// path. 0 when nothing ran.
    pub fn ff_hit_rate(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.ff_cycles as f64 / self.sim_cycles as f64
    }

    /// *Delivered* simulator speed: simulated cycles per host
    /// microsecond counting layer-run cache replays — the number that
    /// reflects what the serving path actually gets per host second.
    pub fn delivered_cycles_per_host_us(&self) -> f64 {
        let nanos = self.sim_wall_nanos + self.replay_nanos;
        if nanos == 0 {
            return 0.0;
        }
        (self.sim_cycles + self.replay_cycles) as f64 * 1e3 / nanos as f64
    }
}

/// Snapshot the current counter values.
pub fn snapshot() -> HostProfile {
    HostProfile {
        sim_wall_nanos: SIM_WALL_NANOS.load(Ordering::Relaxed),
        sim_cycles: SIM_CYCLES.load(Ordering::Relaxed),
        sim_runs: SIM_RUNS.load(Ordering::Relaxed),
        plan_build_nanos: PLAN_BUILD_NANOS.load(Ordering::Relaxed),
        plan_builds: PLAN_BUILDS.load(Ordering::Relaxed),
        ff_cycles: FF_CYCLES.load(Ordering::Relaxed),
        quantize_nanos: QUANTIZE_NANOS.load(Ordering::Relaxed),
        quantizes: QUANTIZES.load(Ordering::Relaxed),
        replay_nanos: REPLAY_NANOS.load(Ordering::Relaxed),
        replay_cycles: REPLAY_CYCLES.load(Ordering::Relaxed),
        replay_runs: REPLAY_RUNS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_are_well_defined() {
        // Pure arithmetic on a local snapshot: the global counters are
        // shared with concurrently running tests, so assertions on
        // them would race — the integration suite covers accumulation.
        let p = HostProfile {
            sim_wall_nanos: 2_000_000,
            sim_cycles: 10_000,
            sim_runs: 2,
            ff_cycles: 7_500,
            ..Default::default()
        };
        assert!((p.sim_wall_ms() - 2.0).abs() < 1e-12);
        assert!((p.sim_cycles_per_host_us() - 5.0).abs() < 1e-12);
        assert!((p.ff_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(HostProfile::default().sim_cycles_per_host_us(), 0.0);
        assert_eq!(HostProfile::default().ff_hit_rate(), 0.0);
        assert_eq!(HostProfile::default().delivered_cycles_per_host_us(), 0.0);
    }

    #[test]
    fn delivered_ratio_counts_replayed_cycles() {
        let p = HostProfile {
            sim_wall_nanos: 1_000_000,
            sim_cycles: 1_000,
            replay_nanos: 1_000_000,
            replay_cycles: 99_000,
            ..Default::default()
        };
        // 100k cycles over 2 ms = 50 cycles/us delivered, vs 1 raw.
        assert!((p.delivered_cycles_per_host_us() - 50.0).abs() < 1e-12);
        assert!((p.sim_cycles_per_host_us() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn recording_accumulates_monotonically() {
        let before = snapshot();
        record_sim(1_000, 500);
        record_plan_build(250);
        record_frep_ff(400);
        record_quantize(100);
        record_replay(50, 500);
        let after = snapshot();
        assert!(after.sim_wall_nanos >= before.sim_wall_nanos + 1_000);
        assert!(after.sim_cycles >= before.sim_cycles + 500);
        assert!(after.sim_runs >= before.sim_runs + 1);
        assert!(after.plan_builds >= before.plan_builds + 1);
        assert!(after.ff_cycles >= before.ff_cycles + 400);
        assert!(after.quantizes >= before.quantizes + 1);
        assert!(after.replay_cycles >= before.replay_cycles + 500);
        assert!(after.replay_runs >= before.replay_runs + 1);
    }
}
