//! Deterministic observability: sim-time span tracing, a typed
//! metrics registry, and Chrome/Perfetto trace export (DESIGN.md §14).
//!
//! The paper's headline numbers rest on *explaining* where cycles go.
//! [`crate::snitch::trace::CycleBreakdown`] does that for one kernel
//! run; this layer extends the attribution across the whole
//! `serve tick → fabric lease → layer → kernel plan/execute →
//! cluster run` hierarchy:
//!
//! * [`span`] — sim-time [`Span`]s collected by an append-only
//!   [`TraceSink`] (per-worker, merge-after-join; no locks);
//! * [`metrics`] — the [`Registry`] of counters/gauges/nearest-rank
//!   histograms exported as `OBS_metrics.json`;
//! * [`perfetto`] — the trace-event JSON exporter behind
//!   `--trace-out` (load the file in <https://ui.perfetto.dev>);
//! * [`hostprof`] — the one sanctioned home for **host** wall-clock
//!   (simulator speed), quarantined under `host_*` keys.
//!
//! **Determinism rules.** Spans and metrics are stamped exclusively in
//! simulated time (cycles = ns at the 1 GHz operating point; 1
//! scheduler tick = [`crate::serve::CYCLES_PER_TICK`] cycles) and are
//! *derived post-hoc* from the simulation's deterministic outcomes
//! ([`crate::serve::scheduler::ServeOutcome`],
//! [`crate::model::PolicyHwRun`], per-cluster stats) rather than
//! threaded through scheduler hot loops. That construction makes the
//! two acceptance properties structural: enabling tracing cannot
//! change a simulated number (the simulation never observes the
//! sink), and disabled tracing is allocation-free (no sink exists).
//! The derivations reconcile exactly with the engine's own
//! accounting: per-fabric serve-span durations sum to the scheduler's
//! busy ticks, asserted in `tests/obs.rs`.

pub mod hostprof;
pub mod metrics;
pub mod perfetto;
pub mod span;

pub use metrics::Registry;
pub use span::{CounterSample, Span, TraceSink};

use crate::fleet::FleetOutcome;
use crate::kernels::MmRun;
use crate::model::PolicyHwRun;
use crate::scaleout::ShardedRun;
use crate::serve::scheduler::ServeOutcome;
use crate::serve::{batches_in_dispatch_order, CostModel, SchedulerKind};
use crate::snitch::cluster::PerfCounters;
use crate::snitch::fpu::FpuCounters;
use crate::snitch::trace::CycleBreakdown;
use crate::workload::arrivals::Priority;
use std::collections::BTreeMap;

/// Process lane for serving-engine tracks (one track per fabric).
pub const PID_SERVE: u32 = 1;
/// Process lane for scale-out cluster tracks (one per cluster).
pub const PID_CLUSTERS: u32 = 2;
/// Process lane for model-graph layer tracks.
pub const PID_MODEL: u32 = 3;
/// Process lane for per-core cycle-attribution tracks.
pub const PID_CORES: u32 = 4;
/// Base process lane for fleet machine tracks: machine `m` traces
/// under pid `PID_FLEET_BASE + m` (DESIGN.md §17), clear of the
/// single-machine lanes above.
pub const PID_FLEET_BASE: u32 = 10;

/// Simulated nanoseconds per scheduler tick (1 cycle = 1 ns at the
/// paper's 1 GHz clock, so this equals
/// [`crate::serve::CYCLES_PER_TICK`]).
pub const NS_PER_TICK: u64 = crate::serve::CYCLES_PER_TICK;

/// Convert scheduler ticks to simulated nanoseconds.
pub fn ticks_to_ns(ticks: u64) -> u64 {
    ticks * NS_PER_TICK
}

/// Stable lowercase label for a scheduling priority.
fn priority_label(p: Priority) -> &'static str {
    match p {
        Priority::High => "high",
        Priority::Normal => "normal",
    }
}

/// Derive the serving timeline of `outcome` as a trace: one track per
/// fabric carrying batch setup/reload overhead spans and per-request
/// service spans, plus a machine-wide queue-depth counter.
///
/// The derivation mirrors the scheduler's busy-tick accounting
/// exactly, so for every fabric `f` the span durations on its track
/// sum to `outcome.fabric_busy_ticks[f]` (in ticks) — the
/// reconciliation invariant `tests/obs.rs` asserts. Barrier batches
/// (which occupy the whole machine and complete as a unit) become one
/// span per batch; continuous batches decompose into setup + reload
/// overhead (split at `costs.setup_ticks`) followed by the
/// back-to-back per-request service spans.
pub fn serve_spans(outcome: &ServeOutcome, costs: &CostModel) -> TraceSink {
    let mut sink = TraceSink::new();
    sink.name_process(PID_SERVE, format!("serving machine ({})", outcome.scheduler.name()));
    for f in 0..outcome.fabric_busy_ticks.len() {
        sink.name_thread(PID_SERVE, f as u32, format!("fabric {f}"));
    }
    for (bi, batch) in batches_in_dispatch_order(outcome).iter().enumerate() {
        let fabric = batch[0].fabric as u32;
        match outcome.scheduler {
            SchedulerKind::Barrier => {
                // The whole batch (setup + member reloads + services)
                // occupies the machine as one unit; its span covers
                // exactly the busy interval the scheduler charged.
                let start = batch[0].dispatch_tick;
                let end = batch[0].complete_tick;
                sink.record(Span {
                    pid: PID_SERVE,
                    tid: fabric,
                    name: format!("batch {bi} ({} req)", batch.len()),
                    cat: "serve.batch",
                    ts_ns: ticks_to_ns(start),
                    dur_ns: ticks_to_ns(end - start),
                    args: vec![
                        ("batch_id", batch[0].batch_id.to_string()),
                        ("requests", batch.len().to_string()),
                    ],
                });
            }
            SchedulerKind::Continuous => {
                // Batch opened at the earliest dispatch; services run
                // back-to-back from the end of the setup+reload
                // overhead. Both facts are reconstructible from the
                // served rows alone because the scheduler stamps
                // dispatch/complete/service ticks per request.
                let open = batch.iter().map(|r| r.dispatch_tick).min().unwrap();
                let first_svc =
                    batch.iter().map(|r| r.complete_tick - r.service_ticks).min().unwrap();
                let overhead = first_svc.saturating_sub(open);
                if overhead > 0 {
                    let setup = overhead.min(costs.setup_ticks);
                    sink.record(Span {
                        pid: PID_SERVE,
                        tid: fabric,
                        name: "setup".to_string(),
                        cat: "serve.setup",
                        ts_ns: ticks_to_ns(open),
                        dur_ns: ticks_to_ns(setup),
                        args: vec![("batch_id", batch[0].batch_id.to_string())],
                    });
                    if overhead > setup {
                        sink.record(Span {
                            pid: PID_SERVE,
                            tid: fabric,
                            name: format!("reload → {}", batch[0].policy),
                            cat: "serve.reload",
                            ts_ns: ticks_to_ns(open + setup),
                            dur_ns: ticks_to_ns(overhead - setup),
                            args: vec![("policy", batch[0].policy.to_string())],
                        });
                    }
                }
                let mut members = batch.clone();
                members.sort_by_key(|r| (r.complete_tick, r.id));
                for r in members {
                    sink.record(Span {
                        pid: PID_SERVE,
                        tid: fabric,
                        name: format!("req {}", r.id),
                        cat: "serve.request",
                        ts_ns: ticks_to_ns(r.complete_tick - r.service_ticks),
                        dur_ns: ticks_to_ns(r.service_ticks),
                        args: vec![
                            ("fmt", r.fmt.name().to_string()),
                            ("policy", r.policy.to_string()),
                            ("priority", priority_label(r.priority).to_string()),
                            ("latency_ticks", r.latency_ticks().to_string()),
                        ],
                    });
                }
            }
        }
    }
    // Machine-wide queued-request depth: +1 at arrival, -1 at
    // dispatch, swept in tick order.
    let mut deltas: BTreeMap<u64, i64> = BTreeMap::new();
    for r in &outcome.served {
        *deltas.entry(r.arrival_tick).or_insert(0) += 1;
        *deltas.entry(r.dispatch_tick).or_insert(0) -= 1;
    }
    let mut depth = 0i64;
    for (tick, d) in deltas {
        depth += d;
        sink.record_counter(CounterSample {
            pid: PID_SERVE,
            name: "queued requests".to_string(),
            ts_ns: ticks_to_ns(tick),
            value: depth as f64,
        });
    }
    sink
}

/// Roll a serve outcome up into the metrics registry: admission and
/// reject counters, per-fabric busy/utilization, per-class maximum
/// queue depth gauges, and latency/service/queue-wait histograms.
/// Pure function of the outcome — byte-stable across identical runs.
pub fn serve_metrics(outcome: &ServeOutcome) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("serve.offered", outcome.offered() as u64);
    reg.counter_add("serve.served", outcome.served.len() as u64);
    reg.counter_add("serve.rejected.queue_full", outcome.rejected_queue_full() as u64);
    reg.counter_add("serve.rejected.slo_unattainable", outcome.rejected_slo() as u64);
    reg.counter_add("serve.batches", outcome.batches as u64);
    reg.counter_add("serve.reloads", outcome.reloads);
    reg.counter_add("serve.horizon_ticks", outcome.horizon_ticks);
    reg.counter_add("serve.slo_ticks", outcome.slo_ticks);
    let horizon = outcome.horizon_ticks.max(1) as f64;
    for (f, &busy) in outcome.fabric_busy_ticks.iter().enumerate() {
        reg.counter_add(&format!("serve.fabric{f}.busy_ticks"), busy);
        reg.gauge_set(&format!("serve.fabric{f}.utilization"), busy as f64 / horizon);
    }
    reg.gauge_set("serve.fabric_utilization", outcome.fabric_utilization());
    reg.gauge_set("serve.mean_batch_size", outcome.mean_batch_size());
    if !outcome.served.is_empty() {
        reg.gauge_set(
            "serve.in_slo_frac",
            outcome.served_in_slo() as f64 / outcome.served.len() as f64,
        );
    }
    for r in &outcome.served {
        reg.hist_record("serve.latency_ticks", r.latency_ticks());
        reg.hist_record("serve.service_ticks", r.service_ticks);
        reg.hist_record(
            "serve.queue_wait_ticks",
            r.dispatch_tick.saturating_sub(r.arrival_tick),
        );
    }
    // Per-class (policy, priority) maximum queue depth, by the same
    // +arrival/-dispatch sweep the machine-wide counter uses.
    let mut class_deltas: BTreeMap<String, BTreeMap<u64, i64>> = BTreeMap::new();
    for r in &outcome.served {
        let key = format!(
            "serve.queue_depth_max.{}.{}",
            r.policy,
            priority_label(r.priority)
        );
        let d = class_deltas.entry(key).or_default();
        *d.entry(r.arrival_tick).or_insert(0) += 1;
        *d.entry(r.dispatch_tick).or_insert(0) -= 1;
    }
    for (key, deltas) in class_deltas {
        let (mut depth, mut max) = (0i64, 0i64);
        for (_, d) in deltas {
            depth += d;
            max = max.max(depth);
        }
        reg.gauge_set(&key, max as f64);
    }
    reg
}

/// Add a [`CycleBreakdown`]'s attribution shares to `reg` under
/// `prefix` (gauges for the per-class fractions, a counter for the
/// cycle total).
pub fn breakdown_metrics(reg: &mut Registry, prefix: &str, bd: &CycleBreakdown) {
    reg.counter_add(&format!("{prefix}.cycles"), bd.cycles);
    reg.gauge_set(&format!("{prefix}.compute"), bd.compute);
    reg.gauge_set(&format!("{prefix}.fp_other"), bd.fp_other);
    reg.gauge_set(&format!("{prefix}.ssr_stall"), bd.ssr_stall);
    reg.gauge_set(&format!("{prefix}.hazard_stall"), bd.hazard_stall);
    reg.gauge_set(&format!("{prefix}.mem_stall"), bd.mem_stall);
    reg.gauge_set(&format!("{prefix}.idle"), bd.idle);
    reg.gauge_set(&format!("{prefix}.conflict_rate"), bd.conflict_rate);
}

/// Metrics rollup of a single-cluster kernel run: throughput,
/// utilization, and the §IV-C cycle breakdown with the run's own
/// compute op as the primary class.
pub fn run_metrics(run: &MmRun, primary: impl Fn(&FpuCounters) -> u64) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("kernel.cycles", run.perf.cycles);
    reg.counter_add("kernel.flops", run.problem.flops());
    reg.gauge_set("kernel.gflops", run.gflops());
    reg.gauge_set("kernel.utilization", run.utilization());
    breakdown_metrics(&mut reg, "kernel.breakdown", &CycleBreakdown::from_perf(&run.perf, primary));
    reg
}

/// Per-core cycle-*attribution* tracks for one cluster run: each
/// core's cycles laid out as consecutive
/// `[compute][fp other][ssr][hazard][mem][idle]` segments.
///
/// This is an attribution layout, not a timeline — the segments show
/// *how many* cycles each class consumed, not *when* (the per-cycle
/// interleaving is not recorded by the performance counters). The
/// `kernel.attrib` category marks them so the distinction is visible
/// in the viewer.
pub fn attribution_spans(
    perf: &PerfCounters,
    primary: impl Fn(&FpuCounters) -> u64,
) -> TraceSink {
    let mut sink = TraceSink::new();
    sink.name_process(PID_CORES, "per-core cycle attribution (layout, not timeline)");
    for (core, c) in perf.fpu.iter().enumerate() {
        sink.name_thread(PID_CORES, core as u32, format!("core {core}"));
        let prim = primary(c);
        let segments: [(&str, u64); 6] = [
            ("compute", prim),
            ("fp other", c.issued.saturating_sub(prim)),
            ("ssr stall", c.stall_ssr),
            ("hazard stall", c.stall_hazard),
            ("mem stall", c.stall_mem),
            ("idle", c.idle),
        ];
        let mut at = 0u64;
        for (name, cycles) in segments {
            if cycles == 0 {
                continue;
            }
            sink.record(Span {
                pid: PID_CORES,
                tid: core as u32,
                name: name.to_string(),
                cat: "kernel.attrib",
                ts_ns: at,
                dur_ns: cycles,
                args: Vec::new(),
            });
            at += cycles;
        }
    }
    sink
}

/// Metrics rollup of a sharded multi-cluster run: machine totals plus
/// per-cluster cycle/shard/pass/mxdotp counters (machine-global
/// cluster ids, as the pool's fabric stats report them).
pub fn sharded_metrics(run: &ShardedRun) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("scaleout.wall_cycles", run.wall_cycles);
    reg.counter_add("scaleout.total_cycles", run.total_cycles);
    reg.counter_add("scaleout.total_mxdotp", run.total_mxdotp);
    reg.counter_add("scaleout.shards", run.shards as u64);
    reg.gauge_set("scaleout.gflops", run.gflops());
    reg.gauge_set("scaleout.energy_uj", run.total_energy_uj);
    for st in &run.clusters {
        let p = format!("scaleout.cluster{}", st.id);
        reg.counter_add(&format!("{p}.cycles"), st.cycles);
        reg.counter_add(&format!("{p}.shards"), st.shards as u64);
        reg.counter_add(&format!("{p}.passes"), st.passes as u64);
        reg.counter_add(&format!("{p}.mxdotp"), st.mxdotp);
        reg.hist_record("scaleout.cluster_cycles", st.cycles);
    }
    reg
}

/// Derive the per-layer timeline of a policy run: one `layers` track
/// with back-to-back spans (the graph executes sequentially, so layer
/// `i` starts at the cumulative wall of layers `0..i`), plus zero-
/// length `MX_FMT` CSR-switch markers on a second track wherever the
/// element format changed between consecutive MX layers. Span
/// durations sum to `run.wall_cycles` exactly.
pub fn policy_spans(run: &PolicyHwRun) -> TraceSink {
    let mut sink = TraceSink::new();
    sink.name_process(PID_MODEL, format!("model graph (policy {})", run.policy));
    sink.name_thread(PID_MODEL, 0, "layers");
    sink.name_thread(PID_MODEL, 1, "csr switches");
    let starts = run.layer_start_cycles();
    let mut prev_fmt = None;
    for (layer, &start) in run.layers.iter().zip(&starts) {
        sink.record(Span {
            pid: PID_MODEL,
            tid: 0,
            name: format!("{} ({})", layer.class.key(), layer.fmt.name()),
            cat: "model.layer",
            ts_ns: start,
            dur_ns: layer.wall_cycles,
            args: vec![
                ("class", layer.class.key().to_string()),
                ("fmt", layer.fmt.name().to_string()),
                ("count", layer.count.to_string()),
                ("gflops", format!("{:.2}", layer.gflops())),
            ],
        });
        if prev_fmt != Some(layer.fmt) {
            sink.record(Span {
                pid: PID_MODEL,
                tid: 1,
                name: format!("MX_FMT → {}", layer.fmt.name()),
                cat: "model.csr",
                ts_ns: start,
                dur_ns: 0,
                args: vec![("fmt", layer.fmt.name().to_string())],
            });
            prev_fmt = Some(layer.fmt);
        }
    }
    sink
}

/// Metrics rollup of a policy run: machine totals, CSR switch count,
/// and per-layer cycle/throughput attribution keyed by layer class.
pub fn policy_metrics(run: &PolicyHwRun) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("model.wall_cycles", run.wall_cycles);
    reg.counter_add("model.flops", run.flops);
    reg.counter_add("model.csr_switches", run.csr_switches as u64);
    reg.gauge_set("model.gflops", run.gflops());
    reg.gauge_set("model.energy_uj", run.total_energy_uj);
    for layer in &run.layers {
        let p = format!("model.layer.{}", layer.class.key());
        reg.counter_add(&format!("{p}.wall_cycles"), layer.wall_cycles);
        reg.counter_add(&format!("{p}.flops"), layer.flops);
        reg.gauge_set(&format!("{p}.gflops"), layer.gflops());
        reg.hist_record("model.layer_wall_cycles", layer.wall_cycles);
    }
    reg
}

/// Derive per-machine fleet tracks from a fleet outcome: machine `m`
/// traces under pid [`PID_FLEET_BASE`]` + m` with one thread per
/// fabric carrying coarse batch spans (first dispatch → last
/// completion), plus an `active machines` counter on the base lane
/// stepping at every autoscaler action.
///
/// These are deliberately batch-granular — the full setup/reload/
/// request decomposition of any one machine is still available by
/// running [`serve_spans`] on `out.machines[m].outcome`; the fleet
/// view exists to show cross-machine placement and lease changes on
/// one timeline. Like every sink in this module it is derived post-hoc
/// from deterministic outcomes, so it is byte-stable across runs.
pub fn fleet_spans(out: &FleetOutcome) -> TraceSink {
    let mut sink = TraceSink::new();
    for m in &out.machines {
        let pid = PID_FLEET_BASE + m.machine as u32;
        sink.name_process(pid, format!("fleet machine {} ({} routed)", m.machine, m.routed));
        for f in 0..m.outcome.fabric_busy_ticks.len() {
            sink.name_thread(pid, f as u32, format!("fabric {f}"));
        }
        for (bi, batch) in batches_in_dispatch_order(&m.outcome).iter().enumerate() {
            let start = batch.iter().map(|r| r.dispatch_tick).min().unwrap();
            let end = batch.iter().map(|r| r.complete_tick).max().unwrap();
            sink.record(Span {
                pid,
                tid: batch[0].fabric as u32,
                name: format!("batch {bi} ({} req)", batch.len()),
                cat: "fleet.batch",
                ts_ns: ticks_to_ns(start),
                dur_ns: ticks_to_ns(end - start),
                args: vec![
                    ("machine", m.machine.to_string()),
                    ("batch_id", batch[0].batch_id.to_string()),
                    ("policy", batch[0].policy.to_string()),
                    ("requests", batch.len().to_string()),
                ],
            });
        }
    }
    // The machine lease over sim time: starts at the pre-first-event
    // lease (the full fleet when no scaler ran) and steps at every
    // scale action.
    let initial = out.scale_events.first().map(|e| e.from).unwrap_or(out.machines.len());
    sink.record_counter(CounterSample {
        pid: PID_FLEET_BASE,
        name: "active machines".to_string(),
        ts_ns: 0,
        value: initial as f64,
    });
    for e in &out.scale_events {
        sink.record_counter(CounterSample {
            pid: PID_FLEET_BASE,
            name: "active machines".to_string(),
            ts_ns: ticks_to_ns(e.tick),
            value: e.to as f64,
        });
    }
    sink
}

/// Roll a fleet outcome up into the metrics registry: fleet totals
/// (conservation-partitioned reject counters, goodput, merged-
/// population latency percentiles), per-machine routing/serving
/// attribution, and per-tenant accounting. The fleet latency
/// histogram records every machine's samples into one population —
/// the merged rollup of DESIGN.md §17, never averaged per-machine
/// percentiles. Pure function of the outcome.
pub fn fleet_metrics(out: &FleetOutcome) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("fleet.machines", out.machines.len() as u64);
    reg.counter_add("fleet.peak_machines", out.peak_machines as u64);
    reg.counter_add("fleet.offered", out.offered() as u64);
    reg.counter_add("fleet.served", out.served() as u64);
    reg.counter_add("fleet.served_in_slo", out.served_in_slo() as u64);
    reg.counter_add("fleet.rejected.machine", out.machine_rejected() as u64);
    reg.counter_add("fleet.rejected.fair_share", out.fleet_rejected.len() as u64);
    reg.counter_add("fleet.scale_events", out.scale_events.len() as u64);
    reg.counter_add("fleet.reloads", out.reloads());
    reg.counter_add("fleet.horizon_ticks", out.horizon_ticks);
    reg.counter_add("fleet.slo_ticks", out.slo_ticks);
    reg.gauge_set("fleet.goodput_per_ktick", out.goodput_per_ktick());
    reg.gauge_set("fleet.throughput_per_ktick", out.throughput_per_ktick());
    reg.gauge_set("fleet.utilization", out.utilization());
    let p = out.percentiles();
    reg.gauge_set("fleet.latency_p50_ticks", p.p50 as f64);
    reg.gauge_set("fleet.latency_p95_ticks", p.p95 as f64);
    reg.gauge_set("fleet.latency_p99_ticks", p.p99 as f64);
    for m in &out.machines {
        let pfx = format!("fleet.machine{}", m.machine);
        reg.counter_add(&format!("{pfx}.routed"), m.routed as u64);
        reg.counter_add(&format!("{pfx}.served"), m.outcome.served.len() as u64);
        reg.counter_add(&format!("{pfx}.rejected"), m.outcome.rejected.len() as u64);
        reg.counter_add(&format!("{pfx}.batches"), m.outcome.batches);
        reg.counter_add(&format!("{pfx}.reloads"), m.outcome.reloads);
        let util =
            if m.outcome.horizon_ticks == 0 { 0.0 } else { m.outcome.fabric_utilization() };
        reg.gauge_set(&format!("{pfx}.utilization"), util);
        for r in &m.outcome.served {
            reg.hist_record("fleet.latency_ticks", r.latency_ticks());
        }
    }
    for t in &out.per_tenant {
        let pfx = format!("fleet.tenant{}", t.tenant);
        reg.counter_add(&format!("{pfx}.offered"), t.offered as u64);
        reg.counter_add(&format!("{pfx}.served"), t.served as u64);
        reg.counter_add(&format!("{pfx}.served_in_slo"), t.served_in_slo as u64);
        reg.counter_add(&format!("{pfx}.rejected.machine"), t.machine_rejected as u64);
        reg.counter_add(&format!("{pfx}.rejected.fair_share"), t.fleet_rejected as u64);
    }
    reg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ElemFormat;
    use crate::serve::{simulate, ServeConfig};
    use crate::workload::arrivals::{ArrivalKind, ArrivalSpec, generate_trace};

    fn outcome(kind: SchedulerKind) -> (ServeOutcome, CostModel) {
        let cfg = ServeConfig { clusters: 2, scheduler: kind, ..ServeConfig::default() };
        let spec = ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick: 4.0,
            mix: vec![(ElemFormat::E4M3, 0.5), (ElemFormat::E2M1, 0.5)],
            high_priority_frac: 0.2,
            requests: 60,
            seed: 11,
        };
        (simulate(&cfg, &generate_trace(&spec)), CostModel::build(&cfg))
    }

    #[test]
    fn serve_spans_reconcile_with_busy_ticks() {
        for kind in [SchedulerKind::Continuous, SchedulerKind::Barrier] {
            let (out, costs) = outcome(kind);
            assert!(!out.served.is_empty(), "{kind}: nothing served");
            let sink = serve_spans(&out, &costs);
            for (f, &busy) in out.fabric_busy_ticks.iter().enumerate() {
                assert_eq!(
                    sink.track_total_ns(PID_SERVE, f as u32),
                    ticks_to_ns(busy),
                    "{kind}: fabric {f} span total must equal its busy ticks"
                );
            }
        }
    }

    #[test]
    fn serve_metrics_account_every_request() {
        let (out, costs) = outcome(SchedulerKind::Continuous);
        let reg = serve_metrics(&out);
        assert_eq!(reg.counter("serve.offered"), out.offered() as u64);
        assert_eq!(
            reg.counter("serve.served")
                + reg.counter("serve.rejected.queue_full")
                + reg.counter("serve.rejected.slo_unattainable"),
            out.offered() as u64,
            "admission counters must partition the offered load"
        );
        assert_eq!(reg.hist_summary("serve.latency_ticks").0, out.served.len());
        // queue-depth sweep returns to zero: everything dispatched
        let sink = serve_spans(&out, &costs);
        let last = sink.counters().last().unwrap();
        assert_eq!(last.value, 0.0, "queue must drain by the end of the run");
    }

    #[test]
    fn ticks_to_ns_matches_the_time_base() {
        assert_eq!(ticks_to_ns(0), 0);
        assert_eq!(ticks_to_ns(3), 3 * crate::serve::CYCLES_PER_TICK);
    }

    #[test]
    fn fleet_rollup_partitions_and_merges() {
        use crate::fleet::{simulate_fleet, FleetConfig, RouterKind};
        let machine = ServeConfig { clusters: 4, fabrics: 2, ..ServeConfig::default() };
        let spec = ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick: 8.0,
            mix: vec![(ElemFormat::E4M3, 0.5), (ElemFormat::E2M1, 0.5)],
            high_priority_frac: 0.0,
            requests: 120,
            seed: 17,
        };
        let out = simulate_fleet(
            &FleetConfig::new(machine, 2, RouterKind::Affinity),
            &generate_trace(&spec),
            &[],
        );
        let reg = fleet_metrics(&out);
        // typed-reject conservation at the fleet level
        assert_eq!(reg.counter("fleet.offered"), 120);
        assert_eq!(
            reg.counter("fleet.served")
                + reg.counter("fleet.rejected.machine")
                + reg.counter("fleet.rejected.fair_share"),
            120
        );
        // the fleet latency histogram is the merged population, and the
        // percentile gauges come from the same order statistics
        let (count, _, p50, _, p99, _, _) = reg.hist_summary("fleet.latency_ticks");
        assert_eq!(count, out.served());
        let p = out.percentiles();
        assert_eq!(p50, p.p50);
        assert_eq!(p99, p.p99);
        assert_eq!(reg.gauge("fleet.latency_p99_ticks"), Some(p.p99 as f64));
        // per-machine attribution covers the whole fleet
        let routed: u64 =
            (0..2).map(|m| reg.counter(&format!("fleet.machine{m}.routed"))).sum();
        assert_eq!(routed, 120);
        // tenant rollup exists even for the untagged single tenant
        assert_eq!(reg.counter("fleet.tenant0.offered"), 120);

        // fleet spans: one process lane per machine, batch spans on
        // fabric threads, and the lease counter present from tick 0
        let sink = fleet_spans(&out);
        assert!(sink
            .counters()
            .first()
            .map(|c| c.ts_ns == 0 && c.value == 2.0)
            .unwrap_or(false));
        // derived twice from the same outcome → byte-identical
        let again = fleet_spans(&out);
        assert_eq!(
            crate::obs::perfetto::render(&sink),
            crate::obs::perfetto::render(&again)
        );
    }
}
