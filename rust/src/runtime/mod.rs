//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! artifacts from `artifacts/*.hlo.txt`.
//!
//! This is the only place the crate touches XLA. Python is never on
//! this path: `make artifacts` ran `python/compile/aot.py` once at
//! build time; here the HLO **text** (not a serialized proto — see
//! DESIGN.md §3) is parsed, compiled for the PJRT CPU client and
//! executed with concrete buffers.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// A compiled model artifact.
pub struct Executable {
    /// Artifact file name this executable was loaded from.
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Directory the artifacts are loaded from.
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifact_dir`.
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, artifact_dir: artifact_dir.into() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact by file name.
    pub fn load(&self, file: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {file}: {e:?}"))?;
        Ok(Executable { name: file.to_string(), exe })
    }

    /// Does the artifact directory contain a compiled model set?
    pub fn artifacts_present(dir: &Path) -> bool {
        dir.join("model.hlo.txt").exists()
    }
}

impl Executable {
    /// Execute with row-major f32 inputs of the given shapes; returns
    /// the flattened f32 outputs (the aot pipeline lowers with
    /// `return_tuple=True`, so the single result is a 1-tuple).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                xla::Literal::vec1(data)
                    .reshape(shape)
                    .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        let tuple = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        tuple
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// One line of `artifacts/manifest.txt`.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    /// Artifact file name.
    pub file: String,
    /// HLO entry computation name.
    pub entry: String,
    /// Free-form detail lines (shapes, notes).
    pub detail: Vec<String>,
}

/// Parse the manifest written by `python/compile/aot.py`.
pub fn parse_manifest(text: &str) -> Vec<ManifestEntry> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let mut parts = l.split_whitespace().map(str::to_string);
            ManifestEntry {
                file: parts.next().unwrap_or_default(),
                entry: parts.next().unwrap_or_default(),
                detail: parts.collect(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let m = parse_manifest(
            "model.hlo.txt deit_block seq=256 dim=192\n\nfp32_matmul.hlo.txt fp32_matmul 64x256x64\n",
        );
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].file, "model.hlo.txt");
        assert_eq!(m[0].entry, "deit_block");
        assert_eq!(m[1].detail, vec!["64x256x64"]);
    }
}
