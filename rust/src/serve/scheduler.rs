//! The two scheduling disciplines under comparison: the seed-style
//! **barrier** batcher and the production **continuous** batcher.
//!
//! Both are deterministic discrete-tick simulations over the same
//! arrival traces and the same analytic cost model, so their outcomes
//! are directly comparable and bit-reproducible:
//!
//! * [`run_barrier`] models the seed coordinator on the whole machine:
//!   one FIFO across formats, dispatch when the batch fills or the
//!   oldest request ages out, the batch occupying the single
//!   whole-machine fabric until **every** member finishes (responses
//!   return at the barrier), weights reloaded on every format
//!   transition the FIFO order happens to produce, and latency-blind
//!   admission (queue-cap backpressure only).
//! * [`run_continuous`] is the engine of DESIGN.md §12: clusters are
//!   grouped into fabrics serving independent batches concurrently; an
//!   idle fabric picks the highest-priority class with the oldest head
//!   request (paying a weight reload only when its resident format
//!   changes); arriving requests **splice into the in-flight batch**
//!   of a matching fabric and complete individually the moment their
//!   own service ends; admission is SLO-aware.
//!
//! Why the barrier collapses under load (the `reproduce serving`
//! table): its FIFO interleaves formats, so ~2·p·(1−p) of adjacent
//! pairs force a weight reload; its responses wait for the whole
//! batch; and above saturation its bounded queue keeps every admitted
//! request waiting `queue_cap / capacity` ticks — far past any SLO —
//! so goodput (SLO-compliant throughput) falls toward zero while raw
//! throughput still looks healthy. The continuous engine rejects what
//! cannot meet the SLO at arrival time and keeps the fabrics on
//! format-stable batches, so its goodput plateaus at machine capacity.

use super::admission::{AdmissionController, RejectReason};
use super::metrics::{latency_percentiles, Percentiles};
use super::queue::ClassQueues;
use super::{CostModel, SchedulerKind, ServeConfig};
use crate::formats::ElemFormat;
use crate::model::PrecisionPolicy;
use crate::workload::arrivals::{Arrival, Priority};
use std::collections::VecDeque;

/// One completed request with its full scheduling attribution. All
/// times are scheduler ticks (1 tick = 1 µs of simulated fabric time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Served {
    /// Trace id of the request.
    pub id: u64,
    /// Element format it advertised (the traffic-mix label).
    pub fmt: ElemFormat,
    /// Per-layer precision policy it was served under (DESIGN.md §13;
    /// uniform-per-format for format-mix traces).
    pub policy: PrecisionPolicy,
    /// Scheduling class priority.
    pub priority: Priority,
    /// When it arrived (and was admitted).
    pub arrival_tick: u64,
    /// When the scheduler placed it into a batch.
    pub dispatch_tick: u64,
    /// When its response was available (barrier: the whole batch's
    /// completion; continuous: its own service completion).
    pub complete_tick: u64,
    /// Service ticks it occupied its fabric for.
    pub service_ticks: u64,
    /// Fabric that served it.
    pub fabric: usize,
    /// Machine-global batch id it was served in.
    pub batch_id: u64,
}

impl Served {
    /// End-to-end latency in ticks (completion − arrival).
    pub fn latency_ticks(&self) -> u64 {
        self.complete_tick - self.arrival_tick
    }
}

/// One rejected request (bounded backpressure — never a silent drop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rejected {
    /// Trace id of the request.
    pub id: u64,
    /// Element format it asked for.
    pub fmt: ElemFormat,
    /// When it arrived.
    pub arrival_tick: u64,
    /// Why admission turned it away.
    pub reason: RejectReason,
}

/// Everything one scheduler run produced. `served` is in dispatch
/// order; every offered request appears exactly once across `served`
/// and `rejected`.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeOutcome {
    /// Discipline that produced this outcome.
    pub scheduler: SchedulerKind,
    /// SLO the run is measured (continuous: also admission-enforced)
    /// against, in ticks.
    pub slo_ticks: u64,
    /// Completed requests in dispatch order.
    pub served: Vec<Served>,
    /// Rejected requests in arrival order.
    pub rejected: Vec<Rejected>,
    /// Simulated span of the run: last completion or last arrival,
    /// whichever is later (≥ 1).
    pub horizon_ticks: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Weight reloads paid (format transitions on some fabric).
    pub reloads: u64,
    /// Busy ticks per fabric (service + setup + reload time).
    pub fabric_busy_ticks: Vec<u64>,
}

impl ServeOutcome {
    /// Requests offered to admission (served + rejected).
    pub fn offered(&self) -> usize {
        self.served.len() + self.rejected.len()
    }

    /// Per-request latencies in ticks, dispatch order.
    pub fn latencies_ticks(&self) -> Vec<u64> {
        self.served.iter().map(Served::latency_ticks).collect()
    }

    /// Latency percentile summary (ticks).
    pub fn percentiles(&self) -> Percentiles {
        latency_percentiles(&self.latencies_ticks())
    }

    /// Served requests that met the SLO.
    pub fn served_in_slo(&self) -> usize {
        self.served.iter().filter(|r| r.latency_ticks() <= self.slo_ticks).count()
    }

    /// Goodput: SLO-compliant completions per kilotick of horizon —
    /// the serving metric the §12 acceptance bar is stated in.
    pub fn goodput_per_ktick(&self) -> f64 {
        self.served_in_slo() as f64 * 1000.0 / self.horizon_ticks as f64
    }

    /// Raw throughput: completions per kilotick of horizon.
    pub fn throughput_per_ktick(&self) -> f64 {
        self.served.len() as f64 * 1000.0 / self.horizon_ticks as f64
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served.len() as f64 / self.batches as f64
        }
    }

    /// Fraction of fabric·ticks spent busy over the horizon.
    pub fn fabric_utilization(&self) -> f64 {
        let busy: u64 = self.fabric_busy_ticks.iter().sum();
        busy as f64 / (self.fabric_busy_ticks.len().max(1) as u64 * self.horizon_ticks) as f64
    }

    /// Rejections due to the queue-depth cap.
    pub fn rejected_queue_full(&self) -> usize {
        self.rejected
            .iter()
            .filter(|r| matches!(r.reason, RejectReason::QueueFull { .. }))
            .count()
    }

    /// Rejections due to SLO unattainability.
    pub fn rejected_slo(&self) -> usize {
        self.rejected
            .iter()
            .filter(|r| matches!(r.reason, RejectReason::SloUnattainable { .. }))
            .count()
    }
}

/// The SLO a run is measured (and, for the continuous scheduler,
/// admission-enforced) against: the explicit config value, or the
/// cost model's auto-SLO when 0. `serve::resolve_slo_ticks` is the
/// public wrapper — this is the single definition.
pub(super) fn effective_slo(cfg: &ServeConfig, costs: &CostModel) -> u64 {
    if cfg.slo_ticks > 0 {
        cfg.slo_ticks
    } else {
        costs.auto_slo_ticks()
    }
}

/// The seed coordinator's discipline on the whole machine (see module
/// docs). `costs` must be built for this config (one whole-machine
/// fabric); `trace` must be tick-sorted.
pub fn run_barrier(cfg: &ServeConfig, costs: &CostModel, trace: &[Arrival]) -> ServeOutcome {
    let slo = effective_slo(cfg, costs);
    let adm = AdmissionController { queue_cap: cfg.queue_cap, slo_ticks: 0 };
    let mut fifo: VecDeque<Arrival> = VecDeque::new();
    let mut served: Vec<Served> = Vec::new();
    let mut rejected: Vec<Rejected> = Vec::new();
    let mut resident: Option<PrecisionPolicy> = None;
    let mut free_at = 0u64;
    let mut busy = 0u64;
    let mut batches = 0u64;
    let mut reloads = 0u64;
    let mut last_complete = 0u64;
    let mut ti = 0usize;
    let mut t = 0u64;
    loop {
        while ti < trace.len() && trace[ti].tick <= t {
            let r = trace[ti];
            ti += 1;
            match adm.admit(fifo.len(), 0, 1, 0) {
                Ok(()) => fifo.push_back(r),
                Err(reason) => {
                    rejected.push(Rejected { id: r.id, fmt: r.fmt, arrival_tick: r.tick, reason })
                }
            }
        }
        if t >= free_at && !fifo.is_empty() {
            let oldest_wait = t.saturating_sub(fifo.front().unwrap().tick);
            if fifo.len() >= cfg.max_batch || oldest_wait >= cfg.max_wait_ticks {
                let n = fifo.len().min(cfg.max_batch);
                let batch_id = batches;
                batches += 1;
                let start = t;
                let mut end = t + costs.setup_ticks;
                // FIFO order is preserved verbatim — including the
                // policy interleaving that forces mid-batch reloads
                // (per-layer: only the weights whose format actually
                // changes between adjacent policies are restaged).
                let mut members: Vec<(Arrival, u64)> = Vec::with_capacity(n);
                for _ in 0..n {
                    let r = fifo.pop_front().unwrap();
                    let reload = costs.reload_ticks_between(resident.as_ref(), &r.policy);
                    if reload > 0 {
                        end += reload;
                        reloads += 1;
                    }
                    resident = Some(r.policy);
                    let svc = costs.svc_policy_ticks(&r.policy);
                    end += svc;
                    members.push((r, svc));
                }
                for (r, svc) in members {
                    // Barrier semantics: every member completes when
                    // the batch does.
                    served.push(Served {
                        id: r.id,
                        fmt: r.fmt,
                        policy: r.policy,
                        priority: r.priority,
                        arrival_tick: r.tick,
                        dispatch_tick: start,
                        complete_tick: end,
                        service_ticks: svc,
                        fabric: 0,
                        batch_id,
                    });
                }
                busy += end - start;
                free_at = end;
                last_complete = last_complete.max(end);
            }
        }
        if ti >= trace.len() && fifo.is_empty() && t >= free_at {
            break;
        }
        t += 1;
    }
    let last_arrival = trace.last().map(|r| r.tick).unwrap_or(0);
    ServeOutcome {
        scheduler: SchedulerKind::Barrier,
        slo_ticks: slo,
        served,
        rejected,
        horizon_ticks: last_complete.max(last_arrival).max(1),
        batches,
        reloads,
        fabric_busy_ticks: vec![busy],
    }
}

/// Fill the remaining splice slots of `f`'s open batch from its
/// resident policy's class queues (High priority first, FIFO within
/// class). Each spliced request is appended at the fabric's tail and
/// completes individually when its own service ends.
#[allow(clippy::too_many_arguments)] // engine-internal plumbing
fn splice_fill(
    f: &mut Fabric,
    fi: usize,
    t: u64,
    costs: &CostModel,
    queues: &mut ClassQueues,
    queued_svc: &mut u64,
    served: &mut Vec<Served>,
    last_complete: &mut u64,
) {
    let Some(policy) = f.resident else { return };
    while f.slots > 0 {
        let Some(r) = queues.pop_policy(&policy) else { break };
        let svc = costs.svc_policy_ticks(&policy);
        *queued_svc -= svc;
        let start = f.tail;
        f.tail = start + svc;
        f.busy += svc;
        f.slots -= 1;
        *last_complete = (*last_complete).max(f.tail);
        served.push(Served {
            id: r.id,
            fmt: r.fmt,
            policy,
            priority: r.priority,
            arrival_tick: r.tick,
            dispatch_tick: t,
            complete_tick: f.tail,
            service_ticks: svc,
            fabric: fi,
            batch_id: f.batch_id,
        });
    }
}

/// Per-fabric scheduling state of the continuous engine.
struct Fabric {
    /// Policy whose weights are currently staged (None = cold).
    resident: Option<PrecisionPolicy>,
    /// Tick when all work assigned to this fabric completes.
    tail: u64,
    /// Remaining splice slots in the open batch (0 = closed).
    slots: usize,
    /// Batch id of the open (or last) batch.
    batch_id: u64,
    /// Accumulated busy ticks (service + setup + reload).
    busy: u64,
}

/// The production discipline (see module docs). `costs` must be built
/// for this config's per-fabric cluster count; `trace` must be
/// tick-sorted.
pub fn run_continuous(cfg: &ServeConfig, costs: &CostModel, trace: &[Arrival]) -> ServeOutcome {
    let fcount = cfg.fabric_count();
    let slo = effective_slo(cfg, costs);
    let adm = AdmissionController { queue_cap: cfg.queue_cap, slo_ticks: slo };
    let mut queues = ClassQueues::new();
    let mut queued_svc = 0u64;
    let mut fabrics: Vec<Fabric> = (0..fcount)
        .map(|_| Fabric { resident: None, tail: 0, slots: 0, batch_id: 0, busy: 0 })
        .collect();
    let mut served: Vec<Served> = Vec::new();
    let mut rejected: Vec<Rejected> = Vec::new();
    let mut batches = 0u64;
    let mut reloads = 0u64;
    let mut last_complete = 0u64;
    let mut ti = 0usize;
    let mut t = 0u64;
    loop {
        while ti < trace.len() && trace[ti].tick <= t {
            let r = trace[ti];
            ti += 1;
            let svc = costs.svc_policy_ticks(&r.policy);
            let inflight: u64 = fabrics.iter().map(|f| f.tail.saturating_sub(t)).sum();
            match adm.admit(
                queues.len(),
                queued_svc + inflight,
                fcount,
                costs.worst_case_policy_ticks(&r.policy),
            ) {
                Ok(()) => {
                    queues.push(r);
                    queued_svc += svc;
                }
                Err(reason) => {
                    rejected.push(Rejected { id: r.id, fmt: r.fmt, arrival_tick: r.tick, reason })
                }
            }
        }
        // Phase 1: fabrics whose work has fully drained close their
        // batch; each queued class is then matched to an idle fabric —
        // preferring one whose *resident format already matches*, so a
        // reload is only paid when no warm idle fabric exists (ties
        // break to the lowest fabric id, keeping the engine
        // deterministic). Idle capacity absorbs queued work *before*
        // any in-flight batch extends its tail — splicing must never
        // add to a busy fabric what an idle one could serve sooner.
        let mut idle: Vec<usize> = (0..fabrics.len()).filter(|&i| t >= fabrics[i].tail).collect();
        for &i in &idle {
            fabrics[i].slots = 0;
        }
        while !idle.is_empty() {
            let Some(class) = queues.pick_class() else { break };
            let pos = idle
                .iter()
                .position(|&i| fabrics[i].resident == Some(class.policy))
                .unwrap_or(0);
            let fi = idle.remove(pos);
            let f = &mut fabrics[fi];
            // Per-layer reload accounting (DESIGN.md §13): only the
            // weighted layers whose format differs from the resident
            // policy's are requantized and restaged.
            let reload = costs.reload_ticks_between(f.resident.as_ref(), &class.policy);
            if reload > 0 {
                reloads += 1;
            }
            f.resident = Some(class.policy);
            let overhead = costs.setup_ticks + reload;
            f.tail = t + overhead;
            f.busy += overhead;
            f.batch_id = batches;
            batches += 1;
            f.slots = cfg.max_batch;
            splice_fill(f, fi, t, costs, &mut queues, &mut queued_svc, &mut served, &mut last_complete);
        }
        // Phase 2: in-flight fabrics with open slots splice
        // same-format arrivals into their running batch — this is
        // where a request admitted mid-batch joins in-flight work
        // instead of waiting for a barrier. Shortest tail first
        // (ties → lowest id), so a queued request joins the
        // *least-loaded* matching fabric, not the first by index.
        let mut open: Vec<usize> = (0..fabrics.len())
            .filter(|&i| t < fabrics[i].tail && fabrics[i].slots > 0)
            .collect();
        open.sort_by_key(|&i| (fabrics[i].tail, i));
        for fi in open {
            let f = &mut fabrics[fi];
            splice_fill(f, fi, t, costs, &mut queues, &mut queued_svc, &mut served, &mut last_complete);
        }
        if ti >= trace.len() && queues.is_empty() && fabrics.iter().all(|f| t >= f.tail) {
            break;
        }
        t += 1;
    }
    let last_arrival = trace.last().map(|r| r.tick).unwrap_or(0);
    ServeOutcome {
        scheduler: SchedulerKind::Continuous,
        slo_ticks: slo,
        served,
        rejected,
        horizon_ticks: last_complete.max(last_arrival).max(1),
        batches,
        reloads,
        fabric_busy_ticks: fabrics.iter().map(|f| f.busy).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::property_cases;
    use crate::serve::simulate;
    use crate::workload::arrivals::{generate_trace, ArrivalKind, ArrivalSpec};
    use crate::workload::DeitConfig;

    /// Small, fast engine config (analytic cost model only — no
    /// cycle-accurate simulation runs in these tests).
    fn small_cfg(sched: SchedulerKind) -> ServeConfig {
        ServeConfig {
            model: DeitConfig { seq: 32, ..DeitConfig::default() },
            clusters: 2,
            scheduler: sched,
            ..ServeConfig::default()
        }
    }

    fn mixed_mix() -> Vec<(ElemFormat, f64)> {
        vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)]
    }

    fn spec(rate: f64, requests: usize, seed: u64) -> ArrivalSpec {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick: rate,
            mix: mixed_mix(),
            high_priority_frac: 0.2,
            requests,
            seed,
        }
    }

    #[test]
    fn barrier_batch_completes_as_a_unit() {
        let cfg = ServeConfig { max_batch: 4, ..small_cfg(SchedulerKind::Barrier) };
        let trace = generate_trace(&spec(4.0, 8, 3));
        let out = simulate(&cfg, &trace);
        assert_eq!(out.offered(), 8);
        for batch in 0..out.batches {
            let ends: Vec<u64> = out
                .served
                .iter()
                .filter(|r| r.batch_id == batch)
                .map(|r| r.complete_tick)
                .collect();
            assert!(!ends.is_empty());
            assert!(ends.iter().all(|&e| e == ends[0]), "batch {batch} not a barrier: {ends:?}");
        }
        // barrier preserves global FIFO dispatch order
        let ids: Vec<u64> = out.served.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
    }

    #[test]
    fn continuous_splices_into_inflight_batches() {
        // One single-cluster fabric, one format: a request arriving
        // while the first batch is in flight must join that batch
        // (same batch id, no second setup) and complete individually.
        let cfg = ServeConfig {
            clusters: 1,
            max_batch: 8,
            ..small_cfg(SchedulerKind::Continuous)
        };
        let costs = CostModel::build(&cfg);
        let svc = costs.svc_ticks(ElemFormat::E4M3);
        let mk = |id, tick| Arrival {
            id,
            tick,
            fmt: ElemFormat::E4M3,
            priority: Priority::Normal,
            policy: PrecisionPolicy::uniform(ElemFormat::E4M3),
        };
        // second request lands mid-service of the first
        let trace = vec![mk(0, 0), mk(1, svc / 2)];
        let out = simulate(&cfg, &trace);
        assert_eq!(out.served.len(), 2);
        assert_eq!(out.batches, 1, "splice must not open a second batch");
        assert_eq!(out.served[0].batch_id, out.served[1].batch_id);
        assert_eq!(out.reloads, 1, "only the initial cold load");
        // individual completions, one service apart
        assert_eq!(
            out.served[1].complete_tick,
            out.served[0].complete_tick + svc,
            "spliced request must complete individually at the tail"
        );
        assert!(out.served[0].latency_ticks() < out.served[1].latency_ticks() + svc);
    }

    #[test]
    fn continuous_prefers_resident_format_and_high_priority() {
        // Two classes queued while the fabric is cold: the High class
        // must be opened first even though the Normal request is older.
        let cfg = ServeConfig { clusters: 1, ..small_cfg(SchedulerKind::Continuous) };
        let mk = |id, tick, fmt, priority| Arrival {
            id,
            tick,
            fmt,
            priority,
            policy: PrecisionPolicy::uniform(fmt),
        };
        let trace = vec![
            mk(0, 0, ElemFormat::E4M3, Priority::Normal),
            mk(1, 1, ElemFormat::E2M1, Priority::High),
        ];
        let out = simulate(&cfg, &trace);
        assert_eq!(out.served.len(), 2);
        // id 0 dispatches first (it arrived while the queue held only
        // its class), but once both are queued High wins: rerun with
        // both present at t=0.
        let trace2 = vec![
            mk(0, 0, ElemFormat::E4M3, Priority::Normal),
            mk(1, 0, ElemFormat::E2M1, Priority::High),
        ];
        let out2 = simulate(&cfg, &trace2);
        assert_eq!(out2.served[0].id, 1, "High-priority class must be scheduled first");
    }

    #[test]
    fn every_offered_request_is_served_or_rejected_with_reason() {
        // The no-silent-drop invariant, under random load and both
        // schedulers.
        property_cases(25, 0x5E12E, |rng| {
            let requests = 1 + rng.below(60) as usize;
            let rate = 0.5 + rng.unit_f64() * 30.0;
            let seed = rng.next_u64();
            let trace = generate_trace(&spec(rate, requests, seed));
            for sched in [SchedulerKind::Barrier, SchedulerKind::Continuous] {
                let cfg = ServeConfig {
                    max_batch: 1 + rng.below(8) as usize,
                    queue_cap: 1 + rng.below(40) as usize,
                    ..small_cfg(sched)
                };
                let out = simulate(&cfg, &trace);
                assert_eq!(out.offered(), requests, "{sched}: lost requests");
                let mut ids: Vec<u64> = out
                    .served
                    .iter()
                    .map(|r| r.id)
                    .chain(out.rejected.iter().map(|r| r.id))
                    .collect();
                ids.sort_unstable();
                let want: Vec<u64> = (0..requests as u64).collect();
                assert_eq!(ids, want, "{sched}: ids not served-or-rejected exactly once");
            }
        });
    }

    #[test]
    fn admission_never_reorders_within_a_class() {
        // Within every (format, priority) class, dispatch order must
        // equal arrival order — under random mixes, priorities, batch
        // sizes and both schedulers.
        property_cases(25, 0xF1F0, |rng| {
            let requests = 2 + rng.below(50) as usize;
            let rate = 1.0 + rng.unit_f64() * 20.0;
            let trace = generate_trace(&spec(rate, requests, rng.next_u64()));
            for sched in [SchedulerKind::Barrier, SchedulerKind::Continuous] {
                let cfg = ServeConfig {
                    max_batch: 1 + rng.below(6) as usize,
                    ..small_cfg(sched)
                };
                let out = simulate(&cfg, &trace);
                for fmt in ElemFormat::ALL {
                    for priority in Priority::ALL {
                        let class_ids: Vec<u64> = out
                            .served
                            .iter()
                            .filter(|r| r.fmt == fmt && r.priority == priority)
                            .map(|r| r.id)
                            .collect();
                        let mut sorted = class_ids.clone();
                        sorted.sort_unstable();
                        assert_eq!(
                            class_ids, sorted,
                            "{sched}: class ({fmt}, {priority:?}) reordered"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn policy_transitions_pay_per_layer_reloads() {
        // all-fp8 -> fp4-ffn shares the qkv/proj weights: the
        // transition must cost strictly less than a full-format switch
        // (all-fp8 -> all-fp4), and the attribution must carry the
        // policies requests arrived with.
        let cfg = ServeConfig { clusters: 1, ..small_cfg(SchedulerKind::Continuous) };
        let costs = CostModel::build(&cfg);
        let fp8 = PrecisionPolicy::preset("all-fp8").unwrap();
        let ffn4 = PrecisionPolicy::preset("fp4-ffn").unwrap();
        let fp4 = PrecisionPolicy::preset("all-fp4").unwrap();
        let partial = costs.reload_ticks_between(Some(&fp8), &ffn4);
        let full = costs.reload_ticks_between(Some(&fp8), &fp4);
        assert!(partial > 0 && partial < full, "partial {partial} vs full {full}");
        assert_eq!(costs.reload_ticks_between(Some(&ffn4), &ffn4), 0);
        // engine run: two policies interleaved on one fabric
        let mk = |id, tick, policy| Arrival {
            id,
            tick,
            fmt: ElemFormat::E4M3,
            priority: Priority::Normal,
            policy,
        };
        let spacing = costs.svc_policy_ticks(&fp8) * 4;
        let trace = vec![
            mk(0, 0, fp8),
            mk(1, spacing, ffn4),
            mk(2, 2 * spacing, fp8),
        ];
        let out = simulate(&cfg, &trace);
        assert_eq!(out.served.len(), 3);
        assert_eq!(out.reloads, 3, "cold + two partial transitions");
        let pols: Vec<PrecisionPolicy> = out.served.iter().map(|r| r.policy).collect();
        assert_eq!(pols, vec![fp8, ffn4, fp8]);
        // mixed-policy service sits between the uniform extremes
        let s8 = costs.svc_policy_ticks(&fp8);
        let s4 = costs.svc_policy_ticks(&fp4);
        let sm = costs.svc_policy_ticks(&ffn4);
        assert!(s4 < sm && sm < s8, "{s4} < {sm} < {s8}");
    }

    #[test]
    fn same_seed_and_trace_give_bit_identical_attribution() {
        for sched in [SchedulerKind::Barrier, SchedulerKind::Continuous] {
            let cfg = small_cfg(sched);
            let trace = generate_trace(&spec(6.0, 80, 11));
            let a = simulate(&cfg, &trace);
            let b = simulate(&cfg, &trace);
            assert_eq!(a, b, "{sched}: outcome not reproducible");
        }
    }

    #[test]
    fn overload_rejects_carry_reasons_and_continuous_meets_its_slo() {
        let cfg = small_cfg(SchedulerKind::Continuous);
        let cap = crate::serve::estimated_capacity_per_ktick(&cfg, &mixed_mix());
        let trace = generate_trace(&spec(4.0 * cap, 150, 21));
        let out = simulate(&cfg, &trace);
        assert!(!out.rejected.is_empty(), "4x overload must shed load");
        assert!(out.rejected_slo() + out.rejected_queue_full() == out.rejected.len());
        // Admission predicts completion under ideal load balancing;
        // real class/fabric skew is bounded, so the served tail stays
        // within a small factor of the enforced SLO and most served
        // requests meet it outright (goodput ≈ throughput).
        let p = out.percentiles();
        assert!(p.p99 <= 2 * out.slo_ticks, "p99 {} way past slo {}", p.p99, out.slo_ticks);
        assert!(
            out.served_in_slo() * 10 >= out.served.len() * 6,
            "only {}/{} served within SLO under admission control",
            out.served_in_slo(),
            out.served.len()
        );
        // fabrics were actually kept busy at overload
        assert!(out.fabric_utilization() > 0.5, "util {}", out.fabric_utilization());
    }
}
