//! Per-(policy, priority) class queues for the continuous batcher.
//!
//! The seed coordinator kept one FIFO and therefore interleaved
//! precision classes in dispatch order, forcing the fabric to
//! requantize and restage weights on every transition (DESIGN.md §12).
//! The serving engine instead queues each *class* — a (precision
//! policy, priority) pair — separately:
//!
//! * order **within** a class is strictly FIFO (arrival order); the
//!   scheduler can only pop from a class head, so admission can never
//!   reorder requests of the same class (property-tested in
//!   `serve::scheduler`);
//! * order **across** classes is a scheduling decision: High-priority
//!   classes are picked strictly before Normal ones, and within a
//!   priority the class with the oldest head request wins (FIFO-fair
//!   across classes, so no policy starves).
//!
//! Before DESIGN.md §13 the class key was the request's element
//! format; it is now the request's full [`PrecisionPolicy`]. Traces
//! generated from a format mix carry uniform per-format policies, so
//! for them the class structure (and every scheduling decision) is
//! unchanged — two requests share a class exactly when they share a
//! format. Policy classes are kept in first-seen order and ties break
//! on (head arrival tick, id), which is total because ids are unique,
//! so scheduling stays deterministic.

use crate::model::PrecisionPolicy;
use crate::workload::arrivals::{Arrival, Priority};
use std::collections::VecDeque;

/// A (precision policy, priority) scheduling class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ClassId {
    /// Precision policy every request in the class carries.
    pub policy: PrecisionPolicy,
    /// Priority of every request in the class.
    pub priority: Priority,
}

/// The class-queue set: one FIFO per (policy, priority) class, created
/// on first use and kept in first-seen order.
#[derive(Clone, Debug, Default)]
pub struct ClassQueues {
    queues: Vec<(ClassId, VecDeque<Arrival>)>,
    len: usize,
}

impl ClassQueues {
    /// Empty queue set.
    pub fn new() -> Self {
        ClassQueues { queues: Vec::new(), len: 0 }
    }

    /// Total queued requests across all classes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no class holds a request.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append `req` to the tail of its class (FIFO within class).
    pub fn push(&mut self, req: Arrival) {
        let class = ClassId { policy: req.policy, priority: req.priority };
        let idx = match self.queues.iter().position(|(c, _)| *c == class) {
            Some(i) => i,
            None => {
                self.queues.push((class, VecDeque::new()));
                self.queues.len() - 1
            }
        };
        self.queues[idx].1.push_back(req);
        self.len += 1;
    }

    /// Pop the head of `policy`'s oldest-head class, High priority
    /// first — the splice path: a fabric resident on `policy` extends
    /// its in-flight batch without a reload.
    pub fn pop_policy(&mut self, policy: &PrecisionPolicy) -> Option<Arrival> {
        for priority in Priority::ALL {
            let class = ClassId { policy: *policy, priority };
            if let Some((_, q)) = self.queues.iter_mut().find(|(c, _)| *c == class) {
                if let Some(req) = q.pop_front() {
                    self.len -= 1;
                    return Some(req);
                }
            }
        }
        None
    }

    /// The class an idle fabric should serve next: the non-empty class
    /// with the highest priority, ties broken by the oldest head
    /// request (then by head id — total, since ids are unique). `None`
    /// when everything is empty.
    pub fn pick_class(&self) -> Option<ClassId> {
        for priority in Priority::ALL {
            let mut best: Option<(u64, u64, ClassId)> = None;
            for (class, q) in &self.queues {
                if class.priority != priority {
                    continue;
                }
                if let Some(head) = q.front() {
                    if best.map(|(t, i, _)| (head.tick, head.id) < (t, i)).unwrap_or(true) {
                        best = Some((head.tick, head.id, *class));
                    }
                }
            }
            if let Some((_, _, class)) = best {
                return Some(class);
            }
        }
        None
    }

    /// Arrival tick of the oldest queued request (across classes).
    pub fn oldest_tick(&self) -> Option<u64> {
        self.queues.iter().filter_map(|(_, q)| q.front().map(|r| r.tick)).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ElemFormat;

    fn req(id: u64, tick: u64, fmt: ElemFormat, priority: Priority) -> Arrival {
        Arrival { id, tick, fmt, priority, policy: PrecisionPolicy::uniform(fmt) }
    }

    #[test]
    fn fifo_within_class_and_priority_between_classes() {
        let e4 = PrecisionPolicy::uniform(ElemFormat::E4M3);
        let mut q = ClassQueues::new();
        q.push(req(0, 5, ElemFormat::E4M3, Priority::Normal));
        q.push(req(1, 6, ElemFormat::E4M3, Priority::Normal));
        q.push(req(2, 7, ElemFormat::E4M3, Priority::High));
        assert_eq!(q.len(), 3);
        // splice order: High head first, then the Normal FIFO
        assert_eq!(q.pop_policy(&e4).unwrap().id, 2);
        assert_eq!(q.pop_policy(&e4).unwrap().id, 0);
        assert_eq!(q.pop_policy(&e4).unwrap().id, 1);
        assert!(q.pop_policy(&e4).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn pick_class_prefers_priority_then_oldest_head() {
        let mut q = ClassQueues::new();
        q.push(req(0, 1, ElemFormat::E4M3, Priority::Normal)); // oldest overall
        q.push(req(1, 9, ElemFormat::E2M1, Priority::High));
        let c = q.pick_class().unwrap();
        assert_eq!(
            (c.policy, c.priority),
            (PrecisionPolicy::uniform(ElemFormat::E2M1), Priority::High)
        );
        q.pop_policy(&c.policy).unwrap();
        // now the oldest head wins among Normal classes
        q.push(req(2, 4, ElemFormat::Int8, Priority::Normal));
        let c = q.pick_class().unwrap();
        assert_eq!(
            (c.policy, c.priority),
            (PrecisionPolicy::uniform(ElemFormat::E4M3), Priority::Normal)
        );
        assert_eq!(q.oldest_tick(), Some(1));
    }

    #[test]
    fn distinct_policies_with_one_format_are_distinct_classes() {
        // fp4-ffn and all-fp8 must not share a FIFO even though both
        // could advertise the same label format.
        let fp8 = PrecisionPolicy::preset("all-fp8").unwrap();
        let ffn4 = PrecisionPolicy::preset("fp4-ffn").unwrap();
        let mut q = ClassQueues::new();
        let mut a = req(0, 0, ElemFormat::E4M3, Priority::Normal);
        a.policy = fp8;
        let mut b = req(1, 1, ElemFormat::E4M3, Priority::Normal);
        b.policy = ffn4;
        q.push(a);
        q.push(b);
        assert_eq!(q.pop_policy(&ffn4).unwrap().id, 1);
        assert_eq!(q.pop_policy(&fp8).unwrap().id, 0);
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queues_pick_nothing() {
        let q = ClassQueues::new();
        assert!(q.pick_class().is_none());
        assert!(q.oldest_tick().is_none());
    }
}
