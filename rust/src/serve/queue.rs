//! Per-(format, priority) class queues for the continuous batcher.
//!
//! The seed coordinator kept one FIFO and therefore interleaved
//! element formats in dispatch order, forcing the fabric to requantize
//! and restage weights on every transition (DESIGN.md §12). The
//! serving engine instead queues each *class* — a (format, priority)
//! pair — separately:
//!
//! * order **within** a class is strictly FIFO (arrival order); the
//!   scheduler can only pop from a class head, so admission can never
//!   reorder requests of the same class (property-tested in
//!   `serve::scheduler`);
//! * order **across** classes is a scheduling decision: High-priority
//!   classes are picked strictly before Normal ones, and within a
//!   priority the class with the oldest head request wins (FIFO-fair
//!   across formats, so no format starves).

use crate::formats::ElemFormat;
use crate::workload::arrivals::{Arrival, Priority};
use std::collections::VecDeque;

/// Number of distinct (format, priority) classes.
const NUM_CLASSES: usize = ElemFormat::ALL.len() * Priority::ALL.len();

/// A (format, priority) scheduling class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ClassId {
    /// Element format of every request in the class.
    pub fmt: ElemFormat,
    /// Priority of every request in the class.
    pub priority: Priority,
}

impl ClassId {
    /// Dense table index (priority-major, format by CSR code).
    fn index(self) -> usize {
        self.priority.index() * ElemFormat::ALL.len() + self.fmt.csr_code() as usize
    }
}

/// The class-queue set: one FIFO per (format, priority) class.
#[derive(Clone, Debug)]
pub struct ClassQueues {
    queues: Vec<VecDeque<Arrival>>,
    len: usize,
}

impl Default for ClassQueues {
    fn default() -> Self {
        Self::new()
    }
}

impl ClassQueues {
    /// Empty queue set (all classes present, all empty).
    pub fn new() -> Self {
        ClassQueues { queues: (0..NUM_CLASSES).map(|_| VecDeque::new()).collect(), len: 0 }
    }

    /// Total queued requests across all classes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no class holds a request.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append `req` to the tail of its class (FIFO within class).
    pub fn push(&mut self, req: Arrival) {
        let class = ClassId { fmt: req.fmt, priority: req.priority };
        self.queues[class.index()].push_back(req);
        self.len += 1;
    }

    /// Pop the head of the oldest-head class of `fmt`, High priority
    /// first — the splice path: a fabric whose resident format is
    /// `fmt` extends its in-flight batch without a reload.
    pub fn pop_fmt(&mut self, fmt: ElemFormat) -> Option<Arrival> {
        for priority in Priority::ALL {
            let idx = ClassId { fmt, priority }.index();
            if let Some(req) = self.queues[idx].pop_front() {
                self.len -= 1;
                return Some(req);
            }
        }
        None
    }

    /// The class an idle fabric should serve next: the non-empty class
    /// with the highest priority, ties broken by the oldest head
    /// request (then by format order, for determinism). `None` when
    /// everything is empty.
    pub fn pick_class(&self) -> Option<ClassId> {
        for priority in Priority::ALL {
            let mut best: Option<(u64, u64, ClassId)> = None;
            for fmt in ElemFormat::ALL {
                let class = ClassId { fmt, priority };
                if let Some(head) = self.queues[class.index()].front() {
                    let key = (head.tick, head.id, class);
                    if best.map(|(t, i, _)| (head.tick, head.id) < (t, i)).unwrap_or(true) {
                        best = Some(key);
                    }
                }
            }
            if let Some((_, _, class)) = best {
                return Some(class);
            }
        }
        None
    }

    /// Arrival tick of the oldest queued request (across classes).
    pub fn oldest_tick(&self) -> Option<u64> {
        self.queues.iter().filter_map(|q| q.front().map(|r| r.tick)).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tick: u64, fmt: ElemFormat, priority: Priority) -> Arrival {
        Arrival { id, tick, fmt, priority }
    }

    #[test]
    fn fifo_within_class_and_priority_between_classes() {
        let mut q = ClassQueues::new();
        q.push(req(0, 5, ElemFormat::E4M3, Priority::Normal));
        q.push(req(1, 6, ElemFormat::E4M3, Priority::Normal));
        q.push(req(2, 7, ElemFormat::E4M3, Priority::High));
        assert_eq!(q.len(), 3);
        // splice order: High head first, then the Normal FIFO
        assert_eq!(q.pop_fmt(ElemFormat::E4M3).unwrap().id, 2);
        assert_eq!(q.pop_fmt(ElemFormat::E4M3).unwrap().id, 0);
        assert_eq!(q.pop_fmt(ElemFormat::E4M3).unwrap().id, 1);
        assert!(q.pop_fmt(ElemFormat::E4M3).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn pick_class_prefers_priority_then_oldest_head() {
        let mut q = ClassQueues::new();
        q.push(req(0, 1, ElemFormat::E4M3, Priority::Normal)); // oldest overall
        q.push(req(1, 9, ElemFormat::E2M1, Priority::High));
        let c = q.pick_class().unwrap();
        assert_eq!((c.fmt, c.priority), (ElemFormat::E2M1, Priority::High));
        q.pop_fmt(ElemFormat::E2M1).unwrap();
        // now the oldest head wins among Normal classes
        q.push(req(2, 4, ElemFormat::Int8, Priority::Normal));
        let c = q.pick_class().unwrap();
        assert_eq!((c.fmt, c.priority), (ElemFormat::E4M3, Priority::Normal));
        assert_eq!(q.oldest_tick(), Some(1));
    }

    #[test]
    fn empty_queues_pick_nothing() {
        let q = ClassQueues::new();
        assert!(q.pick_class().is_none());
        assert!(q.oldest_tick().is_none());
    }
}
