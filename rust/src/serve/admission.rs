//! Admission control: bounded backpressure with explicit,
//! machine-readable rejection reasons.
//!
//! The seed coordinator's queue grew without bound: under sustained
//! overload every admitted request waited longer than the one before
//! it, latency diverged, and *goodput* (requests completed within
//! their SLO) collapsed toward zero even though raw throughput looked
//! healthy — the classic congestion collapse the `reproduce serving`
//! table demonstrates. The admission controller bounds that feedback
//! loop in two ways, both applied at arrival time:
//!
//! * a hard **queue-depth cap** ([`RejectReason::QueueFull`]) — the
//!   memory/backpressure bound;
//! * an **SLO-attainability check** ([`RejectReason::SloUnattainable`])
//!   — the request is rejected *now* if, under ideal load balancing of
//!   the work already accepted, it could not complete within its SLO
//!   anyway. Serving it would waste fabric time on a response the
//!   client has already timed out on.
//!
//! Rejected requests are never silently dropped: every offered request
//! appears exactly once in the outcome, either served or rejected with
//! a reason (property-tested in `serve::scheduler`).

/// Why a request was turned away at admission. All quantities are in
/// scheduler ticks (1 tick = 1 µs of simulated fabric time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is at capacity.
    QueueFull {
        /// Queued requests at the rejection instant.
        depth: usize,
        /// The configured cap.
        cap: usize,
    },
    /// Even under ideal balancing of already-accepted work, this
    /// request could not finish inside its SLO.
    SloUnattainable {
        /// Predicted completion latency (ticks from arrival).
        predicted_ticks: u64,
        /// The SLO it would miss.
        slo_ticks: u64,
    },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { depth, cap } => {
                write!(f, "queue full ({depth}/{cap})")
            }
            RejectReason::SloUnattainable { predicted_ticks, slo_ticks } => {
                write!(f, "SLO unattainable (predicted {predicted_ticks} > slo {slo_ticks} ticks)")
            }
        }
    }
}

/// The admission controller configuration. `slo_ticks == 0` disables
/// the attainability check (the latency-blind mode the seed barrier
/// baseline runs under — queue-cap backpressure only).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionController {
    /// Maximum queued (admitted, not yet dispatched) requests.
    pub queue_cap: usize,
    /// SLO used for the attainability check (0 = disabled).
    pub slo_ticks: u64,
}

impl AdmissionController {
    /// Decide admission for one arriving request.
    ///
    /// * `queued` — requests currently queued;
    /// * `outstanding_ticks` — service ticks of all accepted work not
    ///   yet complete (queued service + in-flight remainders);
    /// * `fabrics` — fabrics the outstanding work is balanced over;
    /// * `request_cost_ticks` — worst-case cost of this request
    ///   (setup + reload + service), making the estimate conservative.
    pub fn admit(
        &self,
        queued: usize,
        outstanding_ticks: u64,
        fabrics: usize,
        request_cost_ticks: u64,
    ) -> Result<(), RejectReason> {
        if queued >= self.queue_cap {
            return Err(RejectReason::QueueFull { depth: queued, cap: self.queue_cap });
        }
        if self.slo_ticks > 0 {
            let predicted = outstanding_ticks / fabrics.max(1) as u64 + request_cost_ticks;
            if predicted > self.slo_ticks {
                return Err(RejectReason::SloUnattainable {
                    predicted_ticks: predicted,
                    slo_ticks: self.slo_ticks,
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_cap_binds_first() {
        let adm = AdmissionController { queue_cap: 2, slo_ticks: 100 };
        assert!(adm.admit(0, 0, 1, 10).is_ok());
        assert!(adm.admit(1, 50, 1, 10).is_ok());
        assert_eq!(
            adm.admit(2, 0, 1, 10),
            Err(RejectReason::QueueFull { depth: 2, cap: 2 })
        );
    }

    #[test]
    fn slo_check_accounts_for_backlog_per_fabric() {
        let adm = AdmissionController { queue_cap: 100, slo_ticks: 100 };
        // 400 outstanding ticks over 4 fabrics = 100 wait + 20 cost
        assert_eq!(
            adm.admit(5, 400, 4, 20),
            Err(RejectReason::SloUnattainable { predicted_ticks: 120, slo_ticks: 100 })
        );
        // same backlog over 8 fabrics fits
        assert!(adm.admit(5, 400, 8, 20).is_ok());
    }

    #[test]
    fn zero_slo_disables_the_attainability_check() {
        let adm = AdmissionController { queue_cap: 10, slo_ticks: 0 };
        assert!(adm.admit(3, u64::MAX / 2, 1, 1000).is_ok());
    }

    #[test]
    fn reasons_render_for_operators() {
        let full = RejectReason::QueueFull { depth: 8, cap: 8 }.to_string();
        assert!(full.contains("queue full"), "{full}");
        let slo =
            RejectReason::SloUnattainable { predicted_ticks: 12, slo_ticks: 9 }.to_string();
        assert!(slo.contains("SLO"), "{slo}");
    }
}
