//! Per-request latency accounting for the serving engine: tail
//! percentiles in simulated ticks (1 tick = 1 µs of fabric time at
//! 1 GHz) plus host wall-clock.
//!
//! The serving claims of DESIGN.md §12 live in the *tail*, not the
//! mean: a barrier batcher and a continuous batcher can have similar
//! means while their p99s differ by an order of magnitude under mixed
//! bursty traffic. Percentiles use the nearest-rank rule, so a
//! reported p99 is always a latency some actual request experienced.

/// Nearest-rank percentile over an **ascending-sorted** slice of tick
/// latencies (`q` in [0, 1]); 0 for an empty slice.
///
/// Total for every input: out-of-range `q` clamps to the nearest end
/// (`q <= 0` → minimum, `q >= 1` → maximum), a NaN `q` behaves as 0
/// (the only order-free choice), and the computed rank is re-clamped
/// to the last index so float rounding can never walk off the slice.
///
/// ```
/// use mxdotp::serve::metrics::percentile_ticks;
/// let sorted = [10, 20, 30, 40];
/// assert_eq!(percentile_ticks(&sorted, 0.0), 10);
/// assert_eq!(percentile_ticks(&sorted, 0.5), 30);
/// assert_eq!(percentile_ticks(&sorted, 1.0), 40);
/// assert_eq!(percentile_ticks(&sorted, 2.5), 40);
/// assert_eq!(percentile_ticks(&sorted, -1.0), 10);
/// assert_eq!(percentile_ticks(&[], 0.99), 0);
/// ```
pub fn percentile_ticks(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    // NaN fails both comparisons below and lands on 0.0; clamp() is
    // avoided because its NaN result would cast to an arbitrary rank.
    let q = if q >= 1.0 {
        1.0
    } else if q >= 0.0 {
        q
    } else {
        0.0
    };
    let idx = (((sorted.len() - 1) as f64 * q).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Latency summary of one serving run, in simulated ticks.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    /// Median latency (ticks).
    pub p50: u64,
    /// 95th-percentile latency (ticks).
    pub p95: u64,
    /// 99th-percentile latency (ticks).
    pub p99: u64,
    /// Mean latency (ticks).
    pub mean: f64,
    /// Worst observed latency (ticks).
    pub max: u64,
    /// Number of samples the summary covers.
    pub count: usize,
}

/// Summarize a set of tick latencies (any order; sorted internally).
pub fn latency_percentiles(latencies: &[u64]) -> Percentiles {
    if latencies.is_empty() {
        return Percentiles::default();
    }
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    Percentiles {
        p50: percentile_ticks(&sorted, 0.50),
        p95: percentile_ticks(&sorted, 0.95),
        p99: percentile_ticks(&sorted, 0.99),
        mean: sorted.iter().sum::<u64>() as f64 / sorted.len() as f64,
        max: *sorted.last().unwrap(),
        count: sorted.len(),
    }
}

/// Fleet-level latency summary over per-machine latency sets: merge
/// every machine's samples into ONE population, then take percentiles
/// (DESIGN.md §17).
///
/// This is the only correct fleet rollup. Averaging per-machine
/// percentiles is wrong whenever machines are skewed — a percentile is
/// an order statistic, not a mean: with one fast machine serving 99
/// requests at 10 ticks and one slow machine serving 1 request at
/// 1000 ticks, the fleet p99 is 10 (99 % of requests finished in 10
/// ticks), while the per-machine-p99 average reports 505 — off by
/// 50×. The regression test below pins exactly that skew.
pub fn merged_latency_percentiles(per_machine: &[Vec<u64>]) -> Percentiles {
    let mut all: Vec<u64> = Vec::with_capacity(per_machine.iter().map(Vec::len).sum());
    for lats in per_machine {
        all.extend_from_slice(lats);
    }
    latency_percentiles(&all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles() {
        let lat: Vec<u64> = (1..=100).collect();
        let p = latency_percentiles(&lat);
        assert_eq!(p.p50, 51); // round(99 * 0.5) = 50 -> value 51
        assert_eq!(p.p95, 95);
        assert_eq!(p.p99, 99);
        assert_eq!(p.max, 100);
        assert_eq!(p.count, 100);
        assert!((p.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample_and_empty() {
        let p = latency_percentiles(&[7]);
        assert_eq!((p.p50, p.p95, p.p99, p.max, p.count), (7, 7, 7, 7, 1));
        assert_eq!(latency_percentiles(&[]), Percentiles::default());
    }

    #[test]
    fn unsorted_input_is_handled() {
        let p = latency_percentiles(&[30, 10, 20]);
        assert_eq!(p.p50, 20);
        assert_eq!(p.max, 30);
    }

    #[test]
    fn merged_percentiles_not_averaged_on_skewed_two_machine_traces() {
        // The fleet-rollup regression (DESIGN.md §17): a fast machine
        // with 99 quick requests and a slow machine with one straggler.
        let fast: Vec<u64> = vec![10; 99];
        let slow: Vec<u64> = vec![1000];
        let merged = merged_latency_percentiles(&[fast.clone(), slow.clone()]);
        // 99 of 100 requests finished in 10 ticks: the fleet
        // p50/p95/p99 are all 10 (the 99th-percentile request IS a
        // 10-tick request), and only max sees the straggler — every
        // reported number is a latency some request actually saw.
        assert_eq!(merged.p50, 10);
        assert_eq!(merged.p95, 10);
        assert_eq!(merged.p99, 10);
        assert_eq!(merged.count, 100);
        assert_eq!(merged.max, 1000);
        // The WRONG rollup — averaging per-machine percentiles — puts
        // the fleet p95 at 505, a latency NO request experienced and
        // 50x the true order statistic. Pin the gap so a refactor can
        // never quietly reintroduce the averaged version.
        let avg_p95 = (latency_percentiles(&fast).p95 + latency_percentiles(&slow).p95) / 2;
        assert_eq!(avg_p95, 505);
        assert!(avg_p95 >= 50 * merged.p95);
        // merging is symmetric and ignores empty machines
        let flipped = merged_latency_percentiles(&[slow, Vec::new(), fast]);
        assert_eq!(flipped, merged);
    }

    #[test]
    fn percentile_is_total_over_degenerate_quantiles() {
        let sorted = [10, 20, 30, 40];
        // out-of-range q clamps to the ends instead of indexing past them
        assert_eq!(percentile_ticks(&sorted, 1.5), 40);
        assert_eq!(percentile_ticks(&sorted, f64::INFINITY), 40);
        assert_eq!(percentile_ticks(&sorted, -0.5), 10);
        assert_eq!(percentile_ticks(&sorted, f64::NEG_INFINITY), 10);
        // NaN behaves as q = 0 — still a value a request experienced
        assert_eq!(percentile_ticks(&sorted, f64::NAN), 10);
        // single- and two-element slices never misrank at the ends
        assert_eq!(percentile_ticks(&[7], 0.0), 7);
        assert_eq!(percentile_ticks(&[7], 1.0), 7);
        assert_eq!(percentile_ticks(&[7], f64::NAN), 7);
        assert_eq!(percentile_ticks(&[3, 9], 0.49), 3);
        assert_eq!(percentile_ticks(&[3, 9], 0.51), 9);
        assert_eq!(percentile_ticks(&[3, 9], 1.0), 9);
        // empty stays 0 for every q, including NaN
        assert_eq!(percentile_ticks(&[], f64::NAN), 0);
        assert_eq!(percentile_ticks(&[], 1.0), 0);
    }
}
