//! The production serving engine (DESIGN.md §12): admission-controlled
//! continuous batching over a multi-fabric MX cluster machine.
//!
//! The seed coordinator (`crate::coordinator`, DESIGN.md §3) is a
//! deliberately lean FIFO-plus-batcher: one queue, barrier dispatch
//! (a batch occupies the whole machine and completes as a unit), no
//! backpressure. That is the right baseline for the paper's
//! single-cluster energy story and it remains in place — but under
//! mixed-format, bursty, open-loop traffic its fabric utilization and
//! goodput collapse. This subsystem replaces it on the serving path:
//!
//! * **per-class queues** ([`queue`]) — one FIFO per (precision
//!   policy, priority) class (uniform per-format policies for
//!   format-mix traces), so scheduling can keep a fabric's resident
//!   weights hot instead of requantizing on every transition; since
//!   DESIGN.md §13 requests carry a full per-layer
//!   [`PrecisionPolicy`], and both the service-time and the
//!   format-switch reload accounting are per-layer
//!   ([`CostModel::svc_policy_ticks`],
//!   [`CostModel::reload_ticks_between`]);
//! * **admission control** ([`admission`]) — bounded queue depth plus
//!   an SLO-attainability check; rejects carry a reason and are never
//!   silently dropped;
//! * **continuous batching + multi-fabric scheduling** ([`scheduler`])
//!   — the machine's clusters are grouped into *fabrics* that serve
//!   independent batches concurrently; arriving requests splice into
//!   in-flight batches instead of waiting for a barrier, and idle
//!   fabrics pick the highest-priority, oldest-head class;
//! * **latency accounting** ([`metrics`]) — p50/p95/p99 in simulated
//!   ticks plus host wall time, surfaced by `report::render_serving`
//!   and `mxdotp-cli reproduce serving`.
//!
//! **Time base.** The engine is a deterministic discrete-tick
//! simulation: 1 tick = [`CYCLES_PER_TICK`] simulated cluster cycles
//! (1 µs at the paper's 1 GHz operating point). Service times come
//! from the analytic cost model (`workload::analytic_sharded_cost`)
//! calibrated against the cycle-accurate simulator, so the serving
//! numbers inherit the paper's per-format throughput ratios (e.g.
//! MXFP4 requests cost half the ticks of MXFP8 ones).
//!
//! **Determinism.** Given a trace (see `workload::arrivals`) and a
//! config, the outcome — every admit/reject decision, batch
//! composition, dispatch and completion tick — is bit-reproducible,
//! and per-request *results* are independent of the scheduler: both
//! schedulers produce bit-identical outputs for every request they
//! both serve ([`verify_schedulers_bit_identical`]).

pub mod admission;
pub mod metrics;
pub mod queue;
pub mod scheduler;

pub use admission::{AdmissionController, RejectReason};
pub use metrics::{latency_percentiles, merged_latency_percentiles, Percentiles};
pub use scheduler::{Rejected, Served};

use crate::formats::ElemFormat;
use crate::model::{GraphExecutor, LayerClass, LayerPrecision, PrecisionPolicy};
use crate::scaleout::pool::FabricLease;
use crate::workload::arrivals::{generate_trace, Arrival, ArrivalKind, ArrivalSpec};
use crate::workload::{
    analytic_policy_cycles_from, analytic_sharded_cost, generate_input, layer_flops_table,
    DeitConfig,
};
use std::collections::HashMap;

/// Simulated cluster cycles per scheduler tick: 1 tick = 1 µs of
/// fabric time at the paper's 1 GHz operating point.
pub const CYCLES_PER_TICK: u64 = 1000;

/// Modeled cost of software-requantizing one weight element during a
/// format reload (cycles per element per core) — the RNE encode path
/// of the FP8-to-FP32 software baseline, which is what a format switch
/// runs before the fabric can serve the new class.
pub const QUANT_CYCLES_PER_ELEM: u64 = 8;

/// Fixed per-batch staging overhead in ticks (plan lookup + activation
/// DMA-in for the first request of a batch).
pub const SETUP_TICKS: u64 = 2;

/// Seed base for deriving a request's input tensor from its trace id
/// (`generate_input(model, INPUT_SEED_BASE + id)`). One shared
/// constant so every executor path — PJRT, in-process, and the
/// scheduler bit-identity check — serves the identical payload for
/// the same trace.
pub const INPUT_SEED_BASE: u64 = 1000;

/// Number of element formats (sizes per-format cost tables).
const NUM_FORMATS: usize = ElemFormat::ALL.len();

/// Which scheduling discipline drives the machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    /// The seed coordinator's model: one FIFO over all formats, one
    /// fabric spanning every cluster, barrier dispatch (the whole
    /// batch completes as a unit), latency-blind admission (queue-cap
    /// backpressure only).
    Barrier,
    /// The production engine: per-class queues, SLO-aware admission,
    /// continuous splice into in-flight batches, concurrent batches on
    /// disjoint fabrics.
    Continuous,
}

impl SchedulerKind {
    /// Canonical lowercase name (CLI value).
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Barrier => "barrier",
            SchedulerKind::Continuous => "continuous",
        }
    }

    /// Parse a lowercase name ("barrier" / "continuous").
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "barrier" => Some(SchedulerKind::Barrier),
            "continuous" => Some(SchedulerKind::Continuous),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Serving-engine configuration: the machine shape, the batching and
/// admission policy, and the scheduling discipline.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Model shapes served (per-request format overrides `model.fmt`).
    pub model: DeitConfig,
    /// Total simulated clusters in the machine.
    pub clusters: usize,
    /// Fabric count for the continuous scheduler (0 = one fabric per
    /// cluster). Must divide `clusters`. The barrier scheduler always
    /// runs one fabric spanning every cluster.
    pub fabrics: usize,
    /// Compute cores per cluster (8 in the paper's cluster).
    pub cores_per_cluster: usize,
    /// Maximum requests per batch (and per continuous batch splice).
    pub max_batch: usize,
    /// Barrier batcher: ticks the oldest request may wait before a
    /// partial batch is dispatched anyway.
    pub max_wait_ticks: u64,
    /// Admission queue-depth cap (bounded backpressure).
    pub queue_cap: usize,
    /// Latency SLO in ticks; 0 = auto (4 × the worst-case single
    /// request cost on one fabric, [`CostModel::auto_slo_ticks`]).
    pub slo_ticks: u64,
    /// Calibrated MX utilization for the analytic cost model
    /// (`workload::calibrate_util`).
    pub util: f64,
    /// Measured strong-scaling efficiency for multi-cluster fabrics
    /// (`scaleout::measure_parallel_efficiency`).
    pub cluster_eff: f64,
    /// Scheduling discipline under simulation.
    pub scheduler: SchedulerKind,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: DeitConfig::default(),
            clusters: 8,
            fabrics: 0,
            cores_per_cluster: crate::snitch::NUM_CORES,
            max_batch: 8,
            max_wait_ticks: 64,
            queue_cap: 128,
            slo_ticks: 0,
            util: 0.78,
            cluster_eff: 0.9,
            scheduler: SchedulerKind::Continuous,
        }
    }
}

impl ServeConfig {
    /// Fabrics the scheduler places batches on: 1 for the barrier
    /// baseline; `fabrics` (or one per cluster when 0) for continuous.
    pub fn fabric_count(&self) -> usize {
        match self.scheduler {
            SchedulerKind::Barrier => 1,
            SchedulerKind::Continuous => {
                if self.fabrics == 0 {
                    self.clusters
                } else {
                    self.fabrics
                }
            }
        }
    }

    /// Clusters backing each fabric (`clusters / fabric_count`).
    pub fn clusters_per_fabric(&self) -> usize {
        self.clusters / self.fabric_count()
    }

    /// The cluster-id range each fabric leases from the machine —
    /// fabric `f` owns clusters `[f·cpf, (f+1)·cpf)`; leases are
    /// pairwise disjoint by construction.
    pub fn fabric_leases(&self) -> Vec<FabricLease> {
        let cpf = self.clusters_per_fabric();
        (0..self.fabric_count())
            .map(|f| FabricLease { first_cluster: f * cpf, clusters: cpf })
            .collect()
    }

    /// Check the config is servable; `Err` carries an operator-facing
    /// message.
    pub fn validate(&self) -> Result<(), String> {
        if self.clusters == 0 {
            return Err("clusters must be at least 1".into());
        }
        let f = self.fabric_count();
        if f == 0 || f > self.clusters || self.clusters % f != 0 {
            return Err(format!(
                "fabrics ({f}) must divide the cluster count ({})",
                self.clusters
            ));
        }
        if self.max_batch == 0 {
            return Err("max_batch must be at least 1 (a zero batch never dispatches)".into());
        }
        if self.queue_cap == 0 {
            return Err("queue_cap must be at least 1".into());
        }
        if !(self.util > 0.0 && self.util <= 1.0) {
            return Err(format!("utilization {} must be in (0, 1]", self.util));
        }
        if self.cores_per_cluster == 0 {
            return Err("cores_per_cluster must be at least 1".into());
        }
        Ok(())
    }
}

/// Per-policy service costs on one fabric, in scheduler ticks —
/// derived from the analytic cost model of `workload/` so the
/// scheduler sees the real per-format throughput differences (MXFP4
/// requests cost half the ticks of byte-wide formats) instead of an
/// average. Since DESIGN.md §13 both halves are **per-layer**: a
/// request's service time sums its policy's layers at each layer's
/// format, and a policy transition reloads only the weights whose
/// format actually changed ([`Self::reload_ticks_between`]).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    svc: [u64; NUM_FORMATS],
    /// Full-machine format-switch cost (every weighted layer
    /// requantized and restaged at [`QUANT_CYCLES_PER_ELEM`] per
    /// element per core across the fabric's clusters) — the cost of a
    /// cold start or a transition between two uniform policies of
    /// different formats. Partial transitions cost less; see
    /// [`Self::reload_ticks_between`].
    pub reload_ticks: u64,
    /// Fixed per-batch staging overhead ([`SETUP_TICKS`]).
    pub setup_ticks: u64,
    /// Clusters backing the fabric this table was built for.
    pub clusters_per_fabric: usize,
    cores_per_cluster: usize,
    util: f64,
    /// Strong-scaling efficiency applied to multi-cluster fabrics
    /// (1.0 for single-cluster fabrics).
    eff: f64,
    /// Fabric-wide VL ([`DeitConfig::vector_len`]): the per-format
    /// `svc` table already prices it (built from the vector-aware
    /// analytic model), and mixed-policy costing bills it per group.
    vector_len: u8,
    /// Per-layer-class MX FLOPs (`workload::layer_flops_table`),
    /// precomputed so per-arrival policy costing allocates nothing.
    layer_flops: [u64; 6],
    /// Per-layer-class weight elements
    /// (`DeitConfig::layer_weight_elems`), precomputed for the same
    /// reason on the reload path.
    layer_welems: [u64; 6],
}

impl CostModel {
    /// Build the cost table for `cfg`'s per-fabric cluster count.
    pub fn build(cfg: &ServeConfig) -> Self {
        let cpf = cfg.clusters_per_fabric();
        let mut svc = [0u64; NUM_FORMATS];
        for fmt in ElemFormat::ALL {
            let m = DeitConfig { fmt, ..cfg.model };
            let cycles = analytic_sharded_cost(
                &m,
                cfg.cores_per_cluster,
                cfg.util,
                cpf,
                cfg.cluster_eff,
            )
            .total
            .cycles;
            svc[fmt.csr_code() as usize] = cycles.div_ceil(CYCLES_PER_TICK).max(1);
        }
        let eff = if cpf > 1 { cfg.cluster_eff.clamp(0.05, 1.0) } else { 1.0 };
        let reload_cycles = (cfg.model.weight_elems() * QUANT_CYCLES_PER_ELEM) as f64
            / (cfg.cores_per_cluster as f64 * cpf as f64 * eff);
        CostModel {
            svc,
            reload_ticks: ((reload_cycles / CYCLES_PER_TICK as f64).ceil() as u64).max(1),
            setup_ticks: SETUP_TICKS,
            clusters_per_fabric: cpf,
            cores_per_cluster: cfg.cores_per_cluster,
            util: cfg.util,
            eff,
            vector_len: cfg.model.vector_len,
            layer_flops: layer_flops_table(&cfg.model),
            layer_welems: LayerClass::ALL.map(|c| cfg.model.layer_weight_elems(c)),
        }
    }

    /// Service ticks of one uniform-`fmt` request on one fabric.
    pub fn svc_ticks(&self, fmt: ElemFormat) -> u64 {
        self.svc[fmt.csr_code() as usize]
    }

    /// Service ticks of one request under `policy`: the per-layer
    /// analytic cost ([`analytic_policy_cycles_from`], over the
    /// precomputed layer-FLOPs table — no allocation per call) sharded
    /// over the fabric. Uniform policies hit the precomputed
    /// per-format table, so format-mix traces cost exactly what they
    /// did before policies existed.
    pub fn svc_policy_ticks(&self, policy: &PrecisionPolicy) -> u64 {
        if let Some(fmt) = policy.uniform_fmt() {
            return self.svc_ticks(fmt);
        }
        let serial = analytic_policy_cycles_from(
            &self.layer_flops,
            policy,
            self.cores_per_cluster,
            self.util,
            self.vector_len,
        );
        let wall = if self.clusters_per_fabric > 1 {
            ((serial as f64) / (self.clusters_per_fabric as f64 * self.eff)).ceil() as u64
        } else {
            serial
        };
        wall.div_ceil(CYCLES_PER_TICK).max(1)
    }

    /// Ticks to requantize and restage the weights a fabric resident
    /// on `from` (None = cold) is missing for `to`: per-layer
    /// accounting — only the weighted layers whose element format
    /// differs contribute ([`PrecisionPolicy::reload_classes_from`]),
    /// so e.g. `all-fp8 → fp4-ffn` pays for the two FFN matrices only.
    /// Returns 0 when nothing needs restaging.
    pub fn reload_ticks_between(
        &self,
        from: Option<&PrecisionPolicy>,
        to: &PrecisionPolicy,
    ) -> u64 {
        // Same per-layer rule as `PrecisionPolicy::reload_classes_from`
        // (which the policy tests pin), inlined over the precomputed
        // weight-elems table so the admission path allocates nothing.
        let mut elems = 0u64;
        for class in LayerClass::ALL {
            if let LayerPrecision::Mx(_) = to.get(class) {
                let stale = match from {
                    None => true,
                    Some(prev) => prev.get(class) != to.get(class),
                };
                if stale {
                    elems += self.layer_welems[class.index()];
                }
            }
        }
        if elems == 0 {
            return 0;
        }
        let cycles = (elems * QUANT_CYCLES_PER_ELEM) as f64
            / (self.cores_per_cluster as f64 * self.clusters_per_fabric as f64 * self.eff);
        ((cycles / CYCLES_PER_TICK as f64).ceil() as u64).max(1)
    }

    /// Worst-case cost of admitting one `fmt` request: a fresh batch
    /// on a cold-format fabric (setup + reload + service).
    pub fn worst_case_request_ticks(&self, fmt: ElemFormat) -> u64 {
        self.worst_case_policy_ticks(&PrecisionPolicy::uniform(fmt))
    }

    /// Worst-case cost of admitting one `policy` request: a fresh
    /// batch on a cold fabric (setup + full per-layer reload +
    /// service).
    pub fn worst_case_policy_ticks(&self, policy: &PrecisionPolicy) -> u64 {
        self.setup_ticks + self.reload_ticks_between(None, policy) + self.svc_policy_ticks(policy)
    }

    /// The auto-SLO: 4 × the worst-case single-request cost of the
    /// slowest format. Generous enough that a lightly loaded fabric
    /// never rejects, tight enough that a saturated barrier queue
    /// (queue-cap deep) blows straight through it.
    pub fn auto_slo_ticks(&self) -> u64 {
        let worst = ElemFormat::ALL
            .iter()
            .map(|&f| self.worst_case_request_ticks(f))
            .max()
            .unwrap_or(1);
        4 * worst
    }
}

/// Resolve the SLO a run of `cfg` is measured (and, for the continuous
/// scheduler, admission-enforced) against: the explicit `slo_ticks`,
/// or the cost model's auto-SLO when 0.
pub fn resolve_slo_ticks(cfg: &ServeConfig) -> u64 {
    scheduler::effective_slo(cfg, &CostModel::build(cfg))
}

/// Estimated steady-state service capacity of the continuous engine in
/// requests per kilotick, for a given traffic mix — the anchor the
/// offered-load sweeps of `report::serving_sweep` and the serving
/// bench are scaled against.
pub fn estimated_capacity_per_ktick(cfg: &ServeConfig, mix: &[(ElemFormat, f64)]) -> f64 {
    let policies: Vec<(PrecisionPolicy, f64)> =
        mix.iter().map(|&(f, w)| (PrecisionPolicy::uniform(f), w)).collect();
    estimated_capacity_for_policies(cfg, &policies)
}

/// [`estimated_capacity_per_ktick`] for a weighted mix of per-layer
/// precision policies (the format-mix version maps each format to its
/// uniform policy and delegates here).
pub fn estimated_capacity_for_policies(
    cfg: &ServeConfig,
    mix: &[(PrecisionPolicy, f64)],
) -> f64 {
    assert!(!mix.is_empty(), "traffic mix must not be empty");
    let c = ServeConfig { scheduler: SchedulerKind::Continuous, ..*cfg };
    let costs = CostModel::build(&c);
    let wsum: f64 = mix.iter().map(|&(_, w)| w).sum();
    let mean_svc: f64 = mix
        .iter()
        .map(|(p, w)| w * costs.svc_policy_ticks(p) as f64)
        .sum::<f64>()
        / wsum;
    c.fabric_count() as f64 * 1000.0 / mean_svc
}

/// The auto-SLO for a machine serving `policy` traffic: 4 × the
/// worst-case single-request cost of that policy (cold fabric: setup +
/// full per-layer reload + service). The format-mix auto-SLO
/// ([`CostModel::auto_slo_ticks`]) covers the uniform per-format
/// envelope; a custom policy — which may quantize the attention GEMMs
/// and cost more than any uniform format — gets its own bound here.
pub fn auto_slo_for_policy(cfg: &ServeConfig, policy: &PrecisionPolicy) -> u64 {
    let costs = CostModel::build(cfg);
    4 * costs.worst_case_policy_ticks(policy)
}

/// Run the configured scheduler over an arrival trace. The outcome is
/// a pure function of `(cfg, trace)` — rerunning yields bit-identical
/// attribution (dispatch/completion ticks, batch ids, reject reasons).
///
/// Panics on an invalid config ([`ServeConfig::validate`]) or an
/// unsorted trace.
pub fn simulate(cfg: &ServeConfig, trace: &[Arrival]) -> scheduler::ServeOutcome {
    if let Err(e) = cfg.validate() {
        panic!("invalid serving config: {e}");
    }
    assert!(
        trace.windows(2).all(|w| w[0].tick <= w[1].tick),
        "arrival trace must be sorted by tick"
    );
    let costs = CostModel::build(cfg);
    match cfg.scheduler {
        SchedulerKind::Barrier => scheduler::run_barrier(cfg, &costs, trace),
        SchedulerKind::Continuous => scheduler::run_continuous(cfg, &costs, trace),
    }
}

/// The scheduler's batches in dispatch order: served requests grouped
/// by (fabric, batch id), preserving the order the batches were
/// formed in. Barrier batches may mix formats (the FIFO interleaving
/// is exactly what the barrier baseline pays reloads for);
/// continuous batches are single-format by construction.
pub fn batches_in_dispatch_order(outcome: &scheduler::ServeOutcome) -> Vec<Vec<Served>> {
    let mut slots: HashMap<(usize, u64), usize> = HashMap::new();
    let mut groups: Vec<Vec<Served>> = Vec::new();
    for r in &outcome.served {
        let slot = *slots.entry((r.fabric, r.batch_id)).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[slot].push(*r);
    }
    groups
}

/// Execute every served request of `outcome` through per-policy
/// executors and return `(request id, output)` pairs sorted by id.
///
/// Batches are executed as the scheduler formed them — grouped by
/// (fabric, batch; mixed-policy barrier batches are sub-split per
/// executor), with batches of the same policy running *concurrently*
/// on disjoint fabrics via [`GraphExecutor::forward_concurrent`] —
/// so this is also the proof that batch composition and placement
/// cannot change results: every output is a pure function of the
/// request id alone. Host concurrency is bounded by the outcome's
/// fabric count (only that many batches were ever in flight at once).
///
/// `execs` must contain an executor for every policy in the outcome
/// (panics otherwise, as does a shape-invalid input).
pub fn execute_outcome(
    outcome: &scheduler::ServeOutcome,
    model: &DeitConfig,
    execs: &HashMap<PrecisionPolicy, GraphExecutor>,
    input_seed_base: u64,
) -> Vec<(u64, Vec<f32>)> {
    let concurrency = outcome.fabric_busy_ticks.len().max(1);
    let groups = batches_in_dispatch_order(outcome);
    // Distinct policies in first-served order (deterministic).
    let mut policies: Vec<PrecisionPolicy> = Vec::new();
    for r in &outcome.served {
        if !policies.contains(&r.policy) {
            policies.push(r.policy);
        }
    }
    let mut results: Vec<(u64, Vec<f32>)> = Vec::with_capacity(outcome.served.len());
    for policy in policies {
        // This policy's slice of each batch, in dispatch order.
        let mut batches: Vec<Vec<Vec<f32>>> = Vec::new();
        let mut ids: Vec<Vec<u64>> = Vec::new();
        for group in &groups {
            let members: Vec<&Served> = group.iter().filter(|r| r.policy == policy).collect();
            if members.is_empty() {
                continue;
            }
            batches
                .push(members.iter().map(|r| generate_input(model, input_seed_base + r.id)).collect());
            ids.push(members.iter().map(|r| r.id).collect());
        }
        if batches.is_empty() {
            continue;
        }
        let exec = execs
            .get(&policy)
            .unwrap_or_else(|| panic!("no executor registered for policy {policy}"));
        // Bound host threads to the machine's fabric count.
        for (batch_chunk, id_chunk) in batches.chunks(concurrency).zip(ids.chunks(concurrency)) {
            let outputs = exec.forward_concurrent(batch_chunk);
            for (batch_ids, batch_out) in id_chunk.iter().zip(outputs) {
                for (&id, out) in batch_ids.iter().zip(batch_out) {
                    results.push((id, out));
                }
            }
        }
    }
    results.sort_by_key(|&(id, _)| id);
    results
}

/// Run the *same* trace through both schedulers, execute every served
/// request with real per-policy [`GraphExecutor`]s, and assert that
/// each request served by both produced bit-identical output — the
/// acceptance invariant that continuous batching reorders *time*, not
/// *results*. Returns the number of requests compared (panics on any
/// mismatch or if the schedulers share no served request).
pub fn verify_schedulers_bit_identical(
    model: &DeitConfig,
    mix: &[(ElemFormat, f64)],
    requests: usize,
    seed: u64,
) -> usize {
    let base = ServeConfig {
        model: *model,
        clusters: 2,
        fabrics: 0,
        ..ServeConfig::default()
    };
    let rate = 0.5 * estimated_capacity_per_ktick(&base, mix);
    let spec = ArrivalSpec {
        kind: ArrivalKind::Poisson,
        rate_per_ktick: rate,
        mix: mix.to_vec(),
        high_priority_frac: 0.0,
        requests,
        seed,
    };
    let trace = generate_trace(&spec);
    let cont = simulate(&ServeConfig { scheduler: SchedulerKind::Continuous, ..base }, &trace);
    let barr = simulate(&ServeConfig { scheduler: SchedulerKind::Barrier, ..base }, &trace);

    let params = crate::workload::generate_params(model, 42);
    let mut execs: HashMap<PrecisionPolicy, GraphExecutor> = HashMap::new();
    for &(fmt, _) in mix {
        let policy = PrecisionPolicy::uniform(fmt);
        execs.entry(policy).or_insert_with(|| {
            GraphExecutor::new(DeitConfig { fmt, ..*model }, policy, params.clone())
                .expect("uniform policy")
        });
    }
    let out_c = execute_outcome(&cont, model, &execs, INPUT_SEED_BASE);
    let out_b = execute_outcome(&barr, model, &execs, INPUT_SEED_BASE);
    let by_id: HashMap<u64, &Vec<f32>> = out_b.iter().map(|(id, o)| (*id, o)).collect();
    let mut compared = 0;
    for (id, oc) in &out_c {
        let Some(ob) = by_id.get(id) else { continue };
        assert_eq!(oc.len(), ob.len(), "request {id}: output shapes differ");
        for (i, (a, b)) in oc.iter().zip(ob.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {id}, element {i}: schedulers disagree ({a} vs {b})"
            );
        }
        compared += 1;
    }
    assert!(compared > 0, "schedulers served disjoint request sets — nothing compared");
    compared
}

/// Warm-up probe: run one small representative MX GEMM on every
/// fabric's leased cluster range through the cycle-accurate scale-out
/// engine ([`crate::scaleout::sharded_mm_leased`]), returning each
/// lease with its measured GFLOPS. This pins the fabric→cluster
/// mapping against the real simulator (per-cluster stats carry
/// machine-global cluster ids) and pre-warms the plan cache the
/// serving executors share.
pub fn probe_fabrics(cfg: &ServeConfig, fmt: ElemFormat) -> Vec<(FabricLease, f64)> {
    let cpf = cfg.clusters_per_fabric();
    let p = crate::kernels::MmProblem {
        m: cfg.cores_per_cluster * cpf,
        k: 64,
        n: 32,
        fmt,
        block_size: 32,
    };
    let mut rng = crate::rng::XorShift::new(0x5E21E);
    let a = rng.normal_vec(p.m * p.k, 0.5);
    let b = rng.normal_vec(p.k * p.n, 0.02);
    let scfg = crate::scaleout::ScaleoutConfig {
        clusters: cpf,
        cores_per_cluster: cfg.cores_per_cluster,
        vector_len: cfg.model.vector_len.max(1) as usize,
        ..crate::scaleout::ScaleoutConfig::default()
    };
    cfg.fabric_leases()
        .into_iter()
        .map(|lease| {
            let run = crate::scaleout::sharded_mm_leased(&scfg, lease, p, &a, &b);
            (lease, run.gflops())
        })
        .collect()
}

/// Stored divergence tolerance for the sampled executor (DESIGN.md
/// §15): the maximum relative error between a spot-checked request's
/// cycle-engine cost and its analytic cost before `--exec sampled:N`
/// fails loudly. Deliberately loose — the analytic model is a
/// calibrated first-order throughput model, not a cycle twin — so this
/// is a drift alarm (the two models disagreeing *wildly* means a bug),
/// not an accuracy gate.
pub const SAMPLED_DIVERGENCE_TOL: f64 = 1.0;

/// Sequence-length cap for the spot-check's reduced model: checking a
/// request on the full serving shapes would cost more cycle-simulation
/// than the analytic executor saved, and the analytic model's error is
/// shape-stable, so the check runs the same policy on a `seq`-capped
/// copy of the model.
pub const SPOT_CHECK_SEQ: usize = 64;

/// Salt XORed into the spot-check RNG seed so the 1-in-N selection
/// stream is decorrelated from the arrival-trace stream that commonly
/// shares the same user-facing seed.
const SPOT_CHECK_SALT: u64 = 0x5907_C4EC_0D15_7A11;

/// One sampled-executor spot check: a served request re-costed on the
/// cycle engine next to its analytic estimate.
#[derive(Clone, Copy, Debug)]
pub struct SpotCheck {
    /// Trace id of the checked request.
    pub id: u64,
    /// Cycle-engine wall cycles of the request's policy on the reduced
    /// ([`SPOT_CHECK_SEQ`]-capped) model, one cluster.
    pub measured_cycles: u64,
    /// Analytic-model cycles for the same reduced model and policy.
    pub analytic_cycles: u64,
    /// `|measured − analytic| / measured` (0 when nothing ran on the
    /// MX fabric, i.e. an all-FP32 policy).
    pub rel_err: f64,
}

/// The outcome of a `--exec sampled:N` spot-check pass: which requests
/// the seeded 1-in-N schedule selected, and how far the analytic model
/// strayed from the cycle engine on each.
#[derive(Clone, Debug)]
pub struct SpotCheckReport {
    /// The N of 1-in-N: each served request is selected with
    /// probability 1/N by the seeded stream.
    pub sample_every: u32,
    /// Served requests in the outcome (the sampling population).
    pub population: usize,
    /// The selected checks, in ascending request-id order.
    pub checks: Vec<SpotCheck>,
    /// Largest relative error across the checks (0 when none ran).
    pub max_rel_err: f64,
    /// Request id carrying `max_rel_err`, if any check ran.
    pub worst_request: Option<u64>,
    /// The tolerance the report is judged against
    /// ([`SAMPLED_DIVERGENCE_TOL`]).
    pub tol: f64,
}

impl SpotCheckReport {
    /// Whether every check stayed within the stored tolerance. An
    /// empty check set passes (nothing diverged).
    pub fn within_tolerance(&self) -> bool {
        self.max_rel_err <= self.tol
    }

    /// Human-readable per-check table plus the verdict line. Pure
    /// simulated quantities — bit-reproducible for a given
    /// (config, outcome, seed).
    pub fn render(&self) -> String {
        let mut s = format!(
            "spot-check (1 in {}): {} of {} served request(s) selected, tol {:.2}\n",
            self.sample_every,
            self.checks.len(),
            self.population,
            self.tol
        );
        for c in &self.checks {
            s.push_str(&format!(
                "  request {:>5}: cycle {:>10} vs analytic {:>10} cycles  rel err {:.4}\n",
                c.id, c.measured_cycles, c.analytic_cycles, c.rel_err
            ));
        }
        match self.worst_request {
            Some(id) if self.within_tolerance() => s.push_str(&format!(
                "  max rel err {:.4} (request {id}) within tolerance — \
                 analytic executor agrees with the cycle engine\n",
                self.max_rel_err
            )),
            Some(id) => s.push_str(&format!(
                "  DIVERGENCE: max rel err {:.4} (request {id}) exceeds tolerance {:.2}\n",
                self.max_rel_err, self.tol
            )),
            None => s.push_str("  no requests selected (empty outcome or sparse schedule)\n"),
        }
        s
    }

    /// The report as deterministic JSON (simulated quantities only) —
    /// written by `reproduce serving --exec sampled:N` so
    /// `tools/check_determinism.py` can byte-compare the spot-check
    /// schedule and verdict across reruns.
    pub fn render_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"sample_every\": {},\n", self.sample_every));
        s.push_str(&format!("  \"population\": {},\n", self.population));
        s.push_str(&format!("  \"tol\": {:.6},\n", self.tol));
        s.push_str(&format!("  \"max_rel_err\": {:.6},\n", self.max_rel_err));
        match self.worst_request {
            Some(id) => s.push_str(&format!("  \"worst_request\": {id},\n")),
            None => s.push_str("  \"worst_request\": null,\n"),
        }
        s.push_str(&format!("  \"within_tolerance\": {},\n", self.within_tolerance()));
        s.push_str("  \"checks\": [\n");
        for (i, c) in self.checks.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"id\": {}, \"measured_cycles\": {}, \"analytic_cycles\": {}, \
                 \"rel_err\": {:.6}}}{}\n",
                c.id,
                c.measured_cycles,
                c.analytic_cycles,
                c.rel_err,
                if i + 1 < self.checks.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Re-cost one policy on both executors for the spot check: the cycle
/// engine runs the policy's model walk on a [`SPOT_CHECK_SEQ`]-capped
/// copy of `model` (one cluster — the analytic per-cluster cost is
/// what calibration targets), the analytic model costs the identical
/// reduced shapes. Returns `(measured_cycles, analytic_cycles)`.
pub fn spot_check_policy(
    model: &DeitConfig,
    policy: &PrecisionPolicy,
    cores_per_cluster: usize,
    util: f64,
    seed: u64,
) -> (u64, u64) {
    let rcfg = DeitConfig { seq: model.seq.min(SPOT_CHECK_SEQ), ..*model };
    let graph = crate::model::ModelGraph::deit_block(&rcfg);
    let measured = crate::model::policy_hw_run(
        &graph,
        policy,
        1,
        cores_per_cluster,
        seed,
        false,
        rcfg.vector_len,
    )
    .wall_cycles;
    let analytic =
        crate::workload::analytic_policy_cycles(&rcfg, policy, cores_per_cluster, util);
    (measured, analytic)
}

/// The `--exec sampled:N` divergence check (DESIGN.md §15): walk the
/// outcome's served requests in ascending-id order, select each with
/// probability 1/N from a seeded [`crate::rng::XorShift`] stream (so
/// the schedule is a pure function of the seed — reruns check the
/// same requests), and re-cost every selected request's policy on the
/// cycle engine via [`spot_check_policy`]. Checks are memoized per
/// policy: the cycle engine is deterministic, so re-simulating a
/// policy already checked in this pass can only reproduce the same
/// number.
///
/// The caller decides what to do with an out-of-tolerance report; the
/// CLI exits non-zero ("fails loudly").
pub fn spot_check_sampled(
    cfg: &ServeConfig,
    outcome: &scheduler::ServeOutcome,
    every: u32,
    seed: u64,
) -> SpotCheckReport {
    assert!(every > 0, "sample rate must be at least 1 (parse-time validated)");
    let mut served: Vec<&Served> = outcome.served.iter().collect();
    served.sort_by_key(|r| r.id);
    let mut rng = crate::rng::XorShift::new(seed ^ SPOT_CHECK_SALT);
    let mut memo: HashMap<PrecisionPolicy, (u64, u64)> = HashMap::new();
    let mut checks = Vec::new();
    for r in served {
        if rng.below(every as u64) != 0 {
            continue;
        }
        let (measured, analytic) = *memo.entry(r.policy).or_insert_with(|| {
            spot_check_policy(&cfg.model, &r.policy, cfg.cores_per_cluster, cfg.util, seed)
        });
        let rel_err = if measured == 0 {
            0.0 // all-FP32 policy: neither model runs anything on the MX fabric
        } else {
            (measured as f64 - analytic as f64).abs() / measured as f64
        };
        checks.push(SpotCheck { id: r.id, measured_cycles: measured, analytic_cycles: analytic, rel_err });
    }
    let mut max_rel_err = 0.0f64;
    let mut worst_request = None;
    for c in &checks {
        if worst_request.is_none() || c.rel_err > max_rel_err {
            max_rel_err = c.rel_err;
            worst_request = Some(c.id);
        }
    }
    SpotCheckReport {
        sample_every: every,
        population: outcome.served.len(),
        checks,
        max_rel_err,
        worst_request,
        tol: SAMPLED_DIVERGENCE_TOL,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_catches_degenerate_shapes() {
        let ok = ServeConfig::default();
        assert!(ok.validate().is_ok());
        assert!(ServeConfig { clusters: 0, ..ok }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..ok }.validate().is_err());
        assert!(ServeConfig { queue_cap: 0, ..ok }.validate().is_err());
        assert!(ServeConfig { fabrics: 3, clusters: 8, ..ok }.validate().is_err());
        assert!(ServeConfig { fabrics: 4, clusters: 8, ..ok }.validate().is_ok());
        assert!(ServeConfig { util: 0.0, ..ok }.validate().is_err());
    }

    #[test]
    fn fabric_leases_partition_the_machine() {
        let cfg = ServeConfig { clusters: 8, fabrics: 4, ..ServeConfig::default() };
        let leases = cfg.fabric_leases();
        assert_eq!(leases.len(), 4);
        assert_eq!(cfg.clusters_per_fabric(), 2);
        for (i, l) in leases.iter().enumerate() {
            assert_eq!(l.first_cluster, 2 * i);
            assert_eq!(l.clusters, 2);
            for other in &leases[i + 1..] {
                assert!(l.is_disjoint(other), "{l:?} overlaps {other:?}");
            }
        }
        // barrier always sees one whole-machine fabric
        let b = ServeConfig { scheduler: SchedulerKind::Barrier, ..cfg };
        assert_eq!(b.fabric_count(), 1);
        assert_eq!(b.clusters_per_fabric(), 8);
    }

    #[test]
    fn cost_model_tracks_format_lane_width_and_fabric_size() {
        let cfg = ServeConfig::default(); // continuous, 1-cluster fabrics
        let costs = CostModel::build(&cfg);
        let f8 = costs.svc_ticks(ElemFormat::E4M3);
        let f4 = costs.svc_ticks(ElemFormat::E2M1);
        // FP4's 16 lanes halve the service time (±1 tick of rounding)
        assert!((f8 as f64 / f4 as f64 - 2.0).abs() < 0.05, "{f8} vs {f4}");
        // the barrier's whole-machine fabric is ~clusters× faster/req
        let bcfg = ServeConfig { scheduler: SchedulerKind::Barrier, ..cfg };
        let bcosts = CostModel::build(&bcfg);
        let bf8 = bcosts.svc_ticks(ElemFormat::E4M3);
        assert!(bf8 < f8 / 4, "barrier per-request svc {bf8} vs single-cluster {f8}");
        // reload is a real cost but smaller than serving one request
        assert!(costs.reload_ticks > 0 && costs.reload_ticks < f8);
        assert!(costs.auto_slo_ticks() > costs.worst_case_request_ticks(ElemFormat::E4M3));
    }

    #[test]
    fn policy_costs_degenerate_to_format_costs_for_uniform_policies() {
        let cfg = ServeConfig::default();
        let costs = CostModel::build(&cfg);
        for fmt in ElemFormat::ALL {
            let p = PrecisionPolicy::uniform(fmt);
            assert_eq!(costs.svc_policy_ticks(&p), costs.svc_ticks(fmt), "{fmt}");
            assert_eq!(
                costs.worst_case_policy_ticks(&p),
                costs.worst_case_request_ticks(fmt),
                "{fmt}"
            );
            // cold reload of a uniform policy = the full-machine reload
            assert_eq!(costs.reload_ticks_between(None, &p), costs.reload_ticks, "{fmt}");
        }
        // the same invariants hold on a multi-cluster fabric
        let wide = ServeConfig { clusters: 8, fabrics: 2, ..cfg };
        let wcosts = CostModel::build(&wide);
        let p = PrecisionPolicy::uniform(ElemFormat::E4M3);
        assert_eq!(wcosts.svc_policy_ticks(&p), wcosts.svc_ticks(ElemFormat::E4M3));
        assert_eq!(wcosts.reload_ticks_between(None, &p), wcosts.reload_ticks);
    }

    #[test]
    fn reload_ticks_derive_from_the_policy_class_rule_property() {
        // The inline per-layer rule in `reload_ticks_between` must
        // agree with `PrecisionPolicy::reload_classes_from` for
        // arbitrary (from, to) policy pairs — partial transitions
        // included — so the serving bill cannot drift from the policy
        // semantics the model layer documents and tests.
        use crate::model::{LayerClass, LayerPrecision};
        let cfg = ServeConfig::default(); // 1-cluster fabrics: eff 1.0
        let costs = CostModel::build(&cfg);
        let random_policy = |rng: &mut crate::rng::XorShift| {
            let mut p = PrecisionPolicy::fp32_reference();
            for class in LayerClass::ALL {
                match rng.below(8) {
                    0 | 1 => {} // stays Fp32
                    i => p.set(class, LayerPrecision::Mx(ElemFormat::ALL[(i % 6) as usize])),
                }
            }
            p
        };
        crate::rng::property_cases(40, 0x2E10AD, |rng| {
            let to = random_policy(rng);
            let from = if rng.bool() { Some(random_policy(rng)) } else { None };
            let elems: u64 = to
                .reload_classes_from(from.as_ref())
                .iter()
                .map(|&c| cfg.model.layer_weight_elems(c))
                .sum();
            let ticks = costs.reload_ticks_between(from.as_ref(), &to);
            if elems == 0 {
                assert_eq!(ticks, 0, "{from:?} -> {to}: no stale weights, no reload");
            } else {
                // the documented formula on the class set the policy
                // layer derives (cores = 8, cpf = 1, eff = 1.0 here)
                let cycles =
                    (elems * QUANT_CYCLES_PER_ELEM) as f64 / cfg.cores_per_cluster as f64;
                let want = ((cycles / CYCLES_PER_TICK as f64).ceil() as u64).max(1);
                assert_eq!(ticks, want, "{from:?} -> {to}");
            }
        });
    }

    #[test]
    fn policy_capacity_and_auto_slo_track_the_mixed_cost() {
        let cfg = ServeConfig::default();
        let fp8 = PrecisionPolicy::preset("all-fp8").unwrap();
        let ffn4 = PrecisionPolicy::preset("fp4-ffn").unwrap();
        let c8 = estimated_capacity_for_policies(&cfg, &[(fp8, 1.0)]);
        let cm = estimated_capacity_for_policies(&cfg, &[(ffn4, 1.0)]);
        assert!(cm > c8 * 1.2, "fp4-ffn capacity {cm} vs all-fp8 {c8}");
        // format-mix capacity is the uniform-policy capacity
        assert_eq!(
            estimated_capacity_per_ktick(&cfg, &[(ElemFormat::E4M3, 1.0)]),
            c8
        );
        let slo8 = auto_slo_for_policy(&cfg, &fp8);
        let slom = auto_slo_for_policy(&cfg, &ffn4);
        assert!(slom < slo8, "cheaper policy must get a tighter auto-SLO");
        // a policy that also quantizes attention costs more than its
        // uniform base (more MX FLOPs on the fabric)
        let mut heavy = fp8;
        heavy.set(
            crate::model::LayerClass::AttnScores,
            crate::model::LayerPrecision::Mx(ElemFormat::E4M3),
        );
        heavy.set(
            crate::model::LayerClass::AttnContext,
            crate::model::LayerPrecision::Mx(ElemFormat::E4M3),
        );
        let costs = CostModel::build(&cfg);
        assert!(costs.svc_policy_ticks(&heavy) > costs.svc_policy_ticks(&fp8));
    }

    #[test]
    fn sampled_spot_check_is_deterministic_and_bounded() {
        let model = DeitConfig { seq: 16, ..DeitConfig::default() };
        let cfg = ServeConfig { model, clusters: 2, ..ServeConfig::default() };
        let mix = [(ElemFormat::E4M3, 1.0)];
        let rate = 0.5 * estimated_capacity_per_ktick(&cfg, &mix);
        let spec = ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick: rate,
            mix: mix.to_vec(),
            high_priority_frac: 0.0,
            requests: 12,
            seed: 7,
        };
        let outcome = simulate(&cfg, &generate_trace(&spec));
        assert!(!outcome.served.is_empty());
        // sampled:1 checks every served request (one memoized cycle
        // run: the trace is single-policy) and the calibrated-ish
        // default utilization stays far inside the loose tolerance
        let all = spot_check_sampled(&cfg, &outcome, 1, 42);
        assert_eq!(all.checks.len(), outcome.served.len());
        assert_eq!(all.population, outcome.served.len());
        assert!(all.worst_request.is_some());
        assert!(all.within_tolerance(), "{}", all.render());
        assert!(all.checks.iter().all(|c| c.measured_cycles > 0));
        // the 1-in-N schedule and verdict are pure functions of the seed
        let a = spot_check_sampled(&cfg, &outcome, 3, 42);
        let b = spot_check_sampled(&cfg, &outcome, 3, 42);
        assert_eq!(
            a.checks.iter().map(|c| c.id).collect::<Vec<_>>(),
            b.checks.iter().map(|c| c.id).collect::<Vec<_>>()
        );
        assert_eq!(a.max_rel_err.to_bits(), b.max_rel_err.to_bits());
        assert_eq!(a.render_json(), b.render_json());
        // checks come back in ascending request-id order
        assert!(a.checks.windows(2).all(|w| w[0].id < w[1].id));
        // the JSON artifact round-trips the verdict fields verbatim
        assert!(all.render_json().contains("\"within_tolerance\": true"));
        assert!(all.render_json().contains(&format!("\"sample_every\": {}", 1)));
    }

    #[test]
    fn capacity_estimate_scales_with_fabrics_and_mix() {
        let cfg = ServeConfig::default();
        let mix8 = [(ElemFormat::E4M3, 1.0)];
        let mix4 = [(ElemFormat::E2M1, 1.0)];
        let c8 = estimated_capacity_per_ktick(&cfg, &mix8);
        let c4 = estimated_capacity_per_ktick(&cfg, &mix4);
        assert!(c4 > 1.8 * c8, "FP4 capacity {c4} vs FP8 {c8}");
        let half = ServeConfig { clusters: 4, ..cfg };
        let ch = estimated_capacity_per_ktick(&half, &mix8);
        assert!((c8 / ch - 2.0).abs() < 0.1, "8-cluster {c8} vs 4-cluster {ch}");
    }
}
