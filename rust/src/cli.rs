//! Hand-rolled CLI (the offline environment has no clap): subcommand
//! parsing for `mxdotp-cli`.
//!
//! ```text
//! mxdotp-cli quantize  --fmt e4m3 --block 32 --n 8 [--seed S]
//! mxdotp-cli simulate  --kernel mx|fp32|fp8sw --m 64 --k 256 --n 64
//!                      [--cores 8] [--fmt e5m2|e4m3|e3m2|e2m3|e2m1|int8] [--seed S]
//! mxdotp-cli reproduce fig3|fig4|table3|formats|scaling|serving|pareto|fleet|training|all
//!                      [--cores 8] [--fmt e4m3] [--rounding rne|stochastic[:SEED]]
//! mxdotp-cli serve     [--requests 16] [--batch 8] [--clusters 8] [--fabrics 0]
//!                      [--mix e4m3:0.6,e2m1:0.4] [--arrival poisson:4]
//!                      [--slo-ticks 0] [--queue-cap 128] [--sched continuous|barrier]
//!                      [--machines 1] [--router affinity|rr]
//! mxdotp-cli info
//! ```
//!
//! Kernel/format compatibility is validated at parse time
//! ([`kernel_for`]): the `mx` hardware kernel takes every OCP element
//! format, `fp8sw` is FP8-only, `fp32` ignores the format.

use crate::fleet::RouterKind;
use crate::formats::{ElemFormat, Rounding};
use crate::kernels::KernelKind;
use crate::model::PrecisionPolicy;
use crate::serve::SchedulerKind;
use crate::workload::arrivals::ArrivalKind;
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // variant fields mirror the documented flags in `USAGE`
pub enum Command {
    /// `quantize`: round-trip a random tensor through one MX format.
    Quantize { fmt: ElemFormat, block: usize, n: usize, seed: u64 },
    /// `simulate`: run one GEMM kernel on the cycle-accurate cluster
    /// (or sharded across a cluster fabric); with `--policy`, walk the
    /// whole per-layer mixed-precision model graph instead.
    Simulate { kernel: KernelKind, m: usize, k: usize, n: usize, cores: usize, clusters: usize, fmt: ElemFormat, seed: u64, cold_plans: bool, policy: Option<PrecisionPolicy>, exec: ExecMode, trace_out: Option<String>, obs_out: Option<String>, vector_len: u8 },
    /// `reproduce`: regenerate the paper's tables/figures and the
    /// extension tables (formats, scaling, serving, pareto, training).
    Reproduce { what: String, cores: usize, clusters: usize, fmt: ElemFormat, cold_plans: bool, policy: Option<PrecisionPolicy>, exec: ExecMode, trace_out: Option<String>, obs_out: Option<String>, vector_len: u8, rounding: Rounding },
    /// `serve`: drive the serving engine over a synthetic arrival
    /// trace, executing served requests through a real executor.
    Serve {
        requests: usize,
        batch: usize,
        clusters: usize,
        fabrics: usize,
        fmt: ElemFormat,
        mix: Vec<(ElemFormat, f64)>,
        arrival: ArrivalKind,
        rate_per_ktick: f64,
        slo_ticks: u64,
        queue_cap: usize,
        sched: SchedulerKind,
        artifacts: String,
        cold_plans: bool,
        policy: Option<PrecisionPolicy>,
        exec: ExecMode,
        trace_out: Option<String>,
        obs_out: Option<String>,
        vector_len: u8,
        machines: usize,
        router: RouterKind,
    },
    /// `info`: print the simulated machine and runtime availability.
    Info,
    /// `help` (also the empty command line).
    Help,
}

/// Resolve a kernel name + element format at parse/dispatch time,
/// rejecting unsupported combinations with the per-kernel format list
/// (instead of dying later on a deep plan assert).
pub fn kernel_for(name: &str, fmt: ElemFormat) -> Result<KernelKind, CliError> {
    let kind = match name {
        "fp32" => KernelKind::Fp32,
        "fp8sw" | "fp8-to-fp32" => KernelKind::Fp8ToFp32,
        "mx" | "mxfp8" => KernelKind::Mx(fmt),
        other => return Err(CliError(format!("unknown kernel '{other}' (mx|fp32|fp8sw)"))),
    };
    if !kind.supported_fmts().contains(&fmt) {
        let supported: Vec<&str> =
            kind.supported_fmts().iter().map(|f| f.name()).collect();
        return Err(CliError(format!(
            "kernel '{name}' does not support --fmt {fmt}; supported formats: {}",
            supported.join(", ")
        )));
    }
    Ok(kind)
}

/// How simulated work is costed (DESIGN.md §15): the cycle-accurate
/// engine, the calibrated analytic model, or the analytic model with a
/// deterministic 1-in-N cycle-engine spot check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Everything runs on the cycle-accurate simulator (default).
    Cycle,
    /// Costs come from the analytic model at the default calibration;
    /// no cycle-accurate simulation runs.
    Analytic,
    /// Analytic costing calibrated by one cycle run, plus a
    /// deterministic 1-in-N cycle-engine spot check that fails loudly
    /// when the models diverge past the stored tolerance.
    Sampled(u32),
}

impl ExecMode {
    /// Parse a `--exec` value (`cycle`, `analytic`, `sampled:N`).
    pub fn parse(s: &str) -> Result<Self, CliError> {
        match s {
            "cycle" => Ok(ExecMode::Cycle),
            "analytic" => Ok(ExecMode::Analytic),
            other => {
                if let Some(n) = other.strip_prefix("sampled:") {
                    let n: u32 = n.parse().map_err(|_| {
                        CliError(format!(
                            "bad --exec sample rate '{n}' (expected sampled:N with integer N >= 1)"
                        ))
                    })?;
                    if n == 0 {
                        return Err(CliError(
                            "--exec sampled:0 would spot-check nothing; the rate must be \
                             at least 1 (sampled:1 checks every request)"
                                .into(),
                        ));
                    }
                    Ok(ExecMode::Sampled(n))
                } else {
                    Err(CliError(format!(
                        "unknown --exec mode '{other}'; supported modes: cycle, analytic, \
                         sampled:N"
                    )))
                }
            }
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Cycle => f.write_str("cycle"),
            ExecMode::Analytic => f.write_str("analytic"),
            ExecMode::Sampled(n) => write!(f, "sampled:{n}"),
        }
    }
}

/// Parse error with a user-facing message.
#[derive(Clone, Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Valueless boolean flags (present = true).
const BOOL_FLAGS: [&str; 1] = ["cold-plans"];

/// Flags the `quantize` subcommand accepts.
const QUANTIZE_FLAGS: &[&str] = &["fmt", "block", "n", "seed"];
/// Flags the `simulate` subcommand accepts.
const SIMULATE_FLAGS: &[&str] = &[
    "kernel", "m", "k", "n", "cores", "clusters", "fmt", "seed", "cold-plans", "policy",
    "exec", "trace-out", "obs-out", "vector-len",
];
/// Flags the `reproduce` subcommand accepts.
const REPRODUCE_FLAGS: &[&str] = &[
    "cores", "clusters", "fmt", "cold-plans", "policy", "exec", "trace-out", "obs-out",
    "vector-len", "rounding",
];
/// Flags the `serve` subcommand accepts.
const SERVE_FLAGS: &[&str] = &[
    "requests", "batch", "clusters", "fabrics", "fmt", "mix", "arrival", "slo-ticks",
    "queue-cap", "sched", "artifacts", "cold-plans", "policy", "exec", "trace-out",
    "obs-out", "vector-len", "machines", "router", "rounding",
];

/// Split `--key value` pairs (plus valueless boolean flags) after the
/// subcommand. Flags outside `known` — typos like `--cold-plan` — are
/// parse errors carrying the subcommand's full flag list, instead of
/// being silently accepted (and silently ignored downstream).
fn flags(args: &[String], known: &[&str]) -> Result<HashMap<String, String>, CliError> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            return Err(CliError(format!("unexpected argument '{k}' (flags are --key value)")));
        }
        let name = k.trim_start_matches("--");
        if !known.contains(&name) {
            let supported: Vec<String> = known.iter().map(|f| format!("--{f}")).collect();
            return Err(CliError(format!(
                "unknown flag '{k}'; supported flags: {}",
                supported.join(", ")
            )));
        }
        if BOOL_FLAGS.contains(&name) {
            map.insert(name.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let v = args
            .get(i + 1)
            .ok_or_else(|| CliError(format!("flag '{k}' needs a value")))?;
        map.insert(name.to_string(), v.clone());
        i += 2;
    }
    Ok(map)
}

/// `--cold-plans`: bypass the plan/pass caches (cold-path measurement).
fn get_cold_plans(f: &HashMap<String, String>) -> bool {
    f.contains_key("cold-plans")
}

/// `--trace-out FILE` / `--obs-out FILE`: observability artifact
/// paths. The parent directory must already exist — checked at parse
/// time so a long simulation cannot die on its final write.
fn get_out_path(
    f: &HashMap<String, String>,
    key: &str,
) -> Result<Option<String>, CliError> {
    let Some(p) = f.get(key) else { return Ok(None) };
    if p.is_empty() {
        return Err(CliError(format!("--{key} needs a file path")));
    }
    if let Some(parent) = std::path::Path::new(p).parent() {
        // an empty parent means the file lands in the current
        // directory, which always exists
        if !parent.as_os_str().is_empty() && !parent.is_dir() {
            return Err(CliError(format!(
                "--{key} {p}: directory '{}' does not exist (create it first)",
                parent.display()
            )));
        }
    }
    Ok(Some(p.clone()))
}

fn get_parse<T: std::str::FromStr>(
    f: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T, CliError> {
    match f.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| CliError(format!("bad value for --{key}: '{v}'"))),
    }
}

/// `--clusters N`: size of the simulated cluster fabric.
fn get_clusters(f: &HashMap<String, String>, default: usize) -> Result<usize, CliError> {
    let clusters: usize = get_parse(f, "clusters", default)?;
    if clusters == 0 {
        return Err(CliError("--clusters must be at least 1".into()));
    }
    Ok(clusters)
}

fn get_fmt(f: &HashMap<String, String>) -> Result<ElemFormat, CliError> {
    match f.get("fmt") {
        None => Ok(ElemFormat::E4M3),
        Some(v) => {
            ElemFormat::parse(v).ok_or_else(|| CliError(format!("unknown format '{v}'")))
        }
    }
}

/// `--batch N`: requests per batch; 0 is rejected at parse time (a
/// zero batch would make the batcher wait forever without
/// dispatching), mirroring the `--clusters 0` rejection.
fn get_batch(f: &HashMap<String, String>) -> Result<usize, CliError> {
    let batch: usize = get_parse(f, "batch", 8)?;
    if batch == 0 {
        return Err(CliError("--batch must be at least 1 (a zero batch never dispatches)".into()));
    }
    Ok(batch)
}

/// `--vector-len N`: MX blocks per dot-product instruction on every
/// core — 1 (the default) runs the scalar `mxdotp` kernel, 2/4/8 the
/// vector `vmxdotp` kernel at that VL. Values outside the hardware's
/// `VECTOR_LEN` CSR set are rejected at parse time (instead of dying
/// later on a deep layout assert).
fn get_vector_len(f: &HashMap<String, String>) -> Result<u8, CliError> {
    let vl: u8 = get_parse(f, "vector-len", 1)?;
    if !crate::dotp::vunit::SUPPORTED_VL.contains(&(vl as usize)) {
        return Err(CliError(format!(
            "--vector-len {vl} is not a supported vector length; \
             supported lengths: 1, 2, 4, 8"
        )));
    }
    Ok(vl)
}

/// `--exec cycle|analytic|sampled:N`: which executor costs the run
/// (default: the cycle-accurate engine).
fn get_exec(f: &HashMap<String, String>) -> Result<ExecMode, CliError> {
    match f.get("exec") {
        None => Ok(ExecMode::Cycle),
        Some(s) => ExecMode::parse(s),
    }
}

/// `--rounding rne|stochastic[:SEED]`: the quantizer rounding mode
/// (DESIGN.md §18). `rne` (the default) rounds to nearest, ties to
/// even; `stochastic` draws deterministic-seeded stochastic rounding
/// at the default seed, `stochastic:SEED` at an explicit decimal u64
/// seed. Unknown modes and malformed seeds are parse errors carrying
/// the supported-value list.
fn get_rounding(f: &HashMap<String, String>) -> Result<Rounding, CliError> {
    match f.get("rounding") {
        None => Ok(Rounding::Rne),
        Some(s) => Rounding::parse(s).map_err(CliError),
    }
}

/// `--policy all-fp8|fp4-ffn|...|class=fmt,...`: a per-layer
/// precision policy (presets or a class=format list layered over the
/// uniform `--fmt` recipe). Unknown layer classes and formats are
/// parse errors carrying the supported-value lists.
fn get_policy(
    f: &HashMap<String, String>,
    fmt: ElemFormat,
) -> Result<Option<PrecisionPolicy>, CliError> {
    match f.get("policy") {
        None => Ok(None),
        Some(s) => PrecisionPolicy::parse(s, PrecisionPolicy::uniform(fmt))
            .map(Some)
            .map_err(CliError),
    }
}

/// `--mix e4m3:0.6,e2m1:0.4`: weighted element-format traffic mix.
fn parse_mix(s: &str) -> Result<Vec<(ElemFormat, f64)>, CliError> {
    if s.trim().is_empty() {
        return Err(CliError(
            "--mix must name at least one fmt:weight pair \
             (e.g. e4m3:0.6,e2m1:0.4; formats: e5m2, e4m3, e3m2, e2m3, e2m1, int8)"
                .into(),
        ));
    }
    let mut mix = Vec::new();
    for part in s.split(',') {
        let Some((name, weight)) = part.split_once(':') else {
            return Err(CliError(format!(
                "bad --mix entry '{part}' (expected fmt:weight, e.g. e4m3:0.6)"
            )));
        };
        let fmt = ElemFormat::parse(name).ok_or_else(|| {
            CliError(format!(
                "unknown format '{name}' in --mix; supported formats: \
                 e5m2, e4m3, e3m2, e2m3, e2m1, int8"
            ))
        })?;
        let w: f64 = weight
            .parse()
            .map_err(|_| CliError(format!("bad weight '{weight}' in --mix")))?;
        if !(w > 0.0 && w.is_finite()) {
            return Err(CliError(format!("--mix weight for {name} must be positive, got {w}")));
        }
        mix.push((fmt, w));
    }
    if mix.is_empty() {
        return Err(CliError("--mix must name at least one fmt:weight pair".into()));
    }
    Ok(mix)
}

/// `--arrival poisson[:RATE] | bursty:RATE:FACTOR:PERIOD` — RATE in
/// requests per kilotick (0 = auto: half the machine's estimated
/// capacity), FACTOR the burst intensity, PERIOD the on/off cycle in
/// ticks.
fn parse_arrival(s: &str) -> Result<(ArrivalKind, f64), CliError> {
    let parts: Vec<&str> = s.split(':').collect();
    let num = |v: &str, what: &str| -> Result<f64, CliError> {
        v.parse::<f64>()
            .ok()
            .filter(|x| x.is_finite() && *x >= 0.0)
            .ok_or_else(|| CliError(format!("bad {what} '{v}' in --arrival")))
    };
    match parts.as_slice() {
        ["poisson"] => Ok((ArrivalKind::Poisson, 0.0)),
        ["poisson", rate] => Ok((ArrivalKind::Poisson, num(rate, "rate")?)),
        ["bursty", rate, factor, period] => {
            let f = num(factor, "burst factor")?;
            if f < 1.0 {
                return Err(CliError(format!("--arrival burst factor must be >= 1, got {f}")));
            }
            let p = num(period, "burst period")?;
            if p < 1.0 {
                return Err(CliError("--arrival burst period must be >= 1 tick".into()));
            }
            Ok((
                ArrivalKind::Bursty { burst_factor: f, period_ticks: p as u64 },
                num(rate, "rate")?,
            ))
        }
        _ => Err(CliError(format!(
            "bad --arrival '{s}' (expected poisson[:RATE] or bursty:RATE:FACTOR:PERIOD)"
        ))),
    }
}

/// Parse a full argument vector (without argv[0]).
pub fn parse(args: &[String]) -> Result<Command, CliError> {
    let Some(cmd) = args.first() else {
        return Ok(Command::Help);
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => Ok(Command::Help),
        "info" => Ok(Command::Info),
        "quantize" => {
            let f = flags(rest, QUANTIZE_FLAGS)?;
            Ok(Command::Quantize {
                fmt: get_fmt(&f)?,
                block: get_parse(&f, "block", 32)?,
                n: get_parse(&f, "n", 8)?,
                seed: get_parse(&f, "seed", 42)?,
            })
        }
        "simulate" => {
            let f = flags(rest, SIMULATE_FLAGS)?;
            let fmt = get_fmt(&f)?;
            let kernel_name = f.get("kernel").map(String::as_str).unwrap_or("mx");
            let kernel = kernel_for(kernel_name, fmt)?;
            let policy = get_policy(&f, fmt)?;
            let exec = get_exec(&f)?;
            let vector_len = get_vector_len(&f)?;
            // Only the MX hardware kernel has a vector datapath behind
            // it; rejecting the combination here beats silently running
            // the scalar fp32/fp8sw kernels at an ignored VL.
            if vector_len > 1 && !matches!(kernel, KernelKind::Mx(_)) {
                return Err(CliError(format!(
                    "--vector-len {vector_len} only applies to the 'mx' hardware kernel \
                     (vmxdotp); the '{kernel_name}' kernel has no vector datapath"
                )));
            }
            // A single-GEMM simulate *is* a cycle run — there is no
            // analytic single-kernel model to swap in — so the analytic
            // and sampled executors only apply to --policy model walks.
            if exec != ExecMode::Cycle && policy.is_none() {
                return Err(CliError(format!(
                    "--exec {exec} only applies to 'simulate --policy ...' model-graph \
                     walks; a plain kernel simulate is inherently a cycle-accurate run"
                )));
            }
            Ok(Command::Simulate {
                kernel,
                m: get_parse(&f, "m", 64)?,
                k: get_parse(&f, "k", 256)?,
                n: get_parse(&f, "n", 64)?,
                cores: get_parse(&f, "cores", 8)?,
                clusters: get_clusters(&f, 1)?,
                fmt,
                seed: get_parse(&f, "seed", 42)?,
                cold_plans: get_cold_plans(&f),
                policy,
                exec,
                trace_out: get_out_path(&f, "trace-out")?,
                obs_out: get_out_path(&f, "obs-out")?,
                vector_len,
            })
        }
        "reproduce" => {
            let what = rest
                .first()
                .filter(|w| !w.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            if !["fig3", "fig4", "table3", "formats", "scaling", "serving", "pareto", "fleet",
                 "training", "all"]
                .contains(&what.as_str())
            {
                return Err(CliError(format!(
                    "unknown target '{what}' \
                     (expected fig3|fig4|table3|formats|scaling|serving|pareto|fleet|\
                     training|all)"
                )));
            }
            let skip = usize::from(!rest.is_empty() && !rest[0].starts_with("--"));
            let f = flags(&rest[skip..], REPRODUCE_FLAGS)?;
            let fmt = get_fmt(&f)?;
            let policy = get_policy(&f, fmt)?;
            // Only the pareto sweep and the training workload consume a
            // policy; silently ignoring it on the other tables would
            // misrepresent what they measured, so reject it up front
            // (like --batch 0).
            if policy.is_some() && what != "pareto" && what != "training" && what != "all" {
                return Err(CliError(format!(
                    "--policy only applies to 'reproduce pareto', 'reproduce training' \
                     (or 'all'), not '{what}' — the other tables sweep --fmt, not \
                     per-layer policies"
                )));
            }
            let rounding = get_rounding(&f)?;
            // Stochastic rounding is a training-time numerics mode
            // (DESIGN.md §18): inference quantizes with RNE so repeated
            // requests stay bit-identical. Reject it on every reproduce
            // target but the training workload.
            if rounding != Rounding::Rne && what != "training" {
                return Err(CliError(format!(
                    "--rounding {rounding} only applies to 'reproduce training' — the \
                     inference targets quantize with RNE so reruns are bit-identical \
                     (DESIGN.md §18)"
                )));
            }
            let exec = get_exec(&f)?;
            // The paper tables (fig3/fig4/table3/formats/scaling) exist
            // to showcase the cycle engine; only the serving comparison
            // and the fleet sweep have an analytic cost model to swap
            // in. Mirror the --policy/pareto restriction instead of
            // silently ignoring the flag.
            if exec != ExecMode::Cycle && what != "serving" && what != "fleet" && what != "all" {
                return Err(CliError(format!(
                    "--exec {exec} only applies to 'reproduce serving', 'reproduce fleet' \
                     (or 'all'), not '{what}' — the paper tables are cycle-accurate by \
                     definition"
                )));
            }
            Ok(Command::Reproduce {
                what,
                cores: get_parse(&f, "cores", 8)?,
                clusters: get_clusters(&f, 8)?,
                fmt,
                cold_plans: get_cold_plans(&f),
                policy,
                exec,
                trace_out: get_out_path(&f, "trace-out")?,
                obs_out: get_out_path(&f, "obs-out")?,
                vector_len: get_vector_len(&f)?,
                rounding,
            })
        }
        "serve" => {
            let f = flags(rest, SERVE_FLAGS)?;
            let fmt = get_fmt(&f)?;
            let clusters = get_clusters(&f, 1)?;
            // An explicit `--fabrics 0` is degenerate (a machine cannot
            // have zero fabrics) and is rejected like `--clusters 0`;
            // *omitting* the flag selects the default of one fabric per
            // cluster.
            let fabrics: usize = match f.get("fabrics") {
                None => 0,
                Some(v) => {
                    let n: usize = v.parse().map_err(|_| {
                        CliError(format!("bad value for --fabrics: '{v}'"))
                    })?;
                    if n == 0 {
                        return Err(CliError(
                            "--fabrics must be at least 1 (omit the flag for the \
                             default of one fabric per cluster)"
                                .into(),
                        ));
                    }
                    n
                }
            };
            if fabrics > 0 && (fabrics > clusters || clusters % fabrics != 0) {
                return Err(CliError(format!(
                    "--fabrics {fabrics} must divide --clusters {clusters}"
                )));
            }
            let policy = get_policy(&f, fmt)?;
            // The serving path quantizes with RNE only: stochastic
            // rounding keys every quantization on a per-tensor seed, so
            // identical requests would stop producing bit-identical
            // responses (and the warm weight-tile cache would fragment
            // per seed). Training is where stochastic rounding lives —
            // see DESIGN.md §18. `--rounding rne` is accepted as the
            // explicit spelling of the default.
            let rounding = get_rounding(&f)?;
            if rounding != Rounding::Rne {
                return Err(CliError(format!(
                    "--rounding {rounding} is not supported on the inference serving \
                     path (serving quantizes with RNE so identical requests produce \
                     bit-identical responses); stochastic rounding applies to \
                     'reproduce training' — see DESIGN.md §18"
                )));
            }
            if policy.is_some() && f.contains_key("mix") {
                return Err(CliError(
                    "--policy and --mix are mutually exclusive: --mix weights \
                     single-format traffic classes, --policy makes every request \
                     carry one per-layer policy"
                        .into(),
                ));
            }
            let mix = match f.get("mix") {
                None => vec![(fmt, 1.0)],
                Some(s) => parse_mix(s)?,
            };
            let (arrival, rate_per_ktick) = match f.get("arrival") {
                None => (ArrivalKind::Poisson, 0.0),
                Some(s) => parse_arrival(s)?,
            };
            let sched = match f.get("sched") {
                None => SchedulerKind::Continuous,
                Some(s) => SchedulerKind::parse(s).ok_or_else(|| {
                    CliError(format!("unknown scheduler '{s}' (continuous|barrier)"))
                })?,
            };
            let queue_cap: usize = get_parse(&f, "queue-cap", 128)?;
            if queue_cap == 0 {
                return Err(CliError("--queue-cap must be at least 1".into()));
            }
            let machines: usize = get_parse(&f, "machines", 1)?;
            if machines == 0 {
                return Err(CliError(
                    "--machines must be at least 1 (the fleet needs a machine to route to)"
                        .into(),
                ));
            }
            let router = match f.get("router") {
                None => RouterKind::Affinity,
                Some(s) => RouterKind::parse(s).map_err(CliError)?,
            };
            let exec = get_exec(&f)?;
            // The fleet path replays the trace through N replicated
            // analytic serving engines; there is no fleet-wide cycle
            // loop to fall back to. Reject rather than silently
            // downgrade the executor.
            if machines > 1 && exec == ExecMode::Cycle {
                return Err(CliError(format!(
                    "--machines {machines} runs the fleet simulator, which costs requests \
                     with the calibrated analytic model — pass --exec analytic or \
                     --exec sampled:N (the spot-checked variant)"
                )));
            }
            Ok(Command::Serve {
                requests: get_parse(&f, "requests", 16)?,
                batch: get_batch(&f)?,
                clusters,
                fabrics,
                fmt,
                mix,
                arrival,
                rate_per_ktick,
                slo_ticks: get_parse(&f, "slo-ticks", 0)?,
                queue_cap,
                sched,
                artifacts: f.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
                cold_plans: get_cold_plans(&f),
                policy,
                exec,
                trace_out: get_out_path(&f, "trace-out")?,
                obs_out: get_out_path(&f, "obs-out")?,
                vector_len: get_vector_len(&f)?,
                machines,
                router,
            })
        }
        other => Err(CliError(format!("unknown subcommand '{other}' (try 'help')"))),
    }
}

/// The help text printed by `mxdotp-cli help` (and on parse errors).
pub const USAGE: &str = "\
mxdotp-cli — MXDOTP paper reproduction driver

USAGE:
  mxdotp-cli quantize  [--fmt e4m3|e5m2|e3m2|e2m3|e2m1|int8] [--block 32] [--n 8] [--seed S]
  mxdotp-cli simulate  [--kernel mx|fp32|fp8sw] [--m 64] [--k 256] [--n 64]
                       [--cores 8] [--clusters 1] [--fmt e4m3] [--seed S] [--cold-plans]
                       [--vector-len 1|2|4|8]
                       [--policy PRESET|class=fmt,...] [--exec cycle|analytic|sampled:N]
                       [--trace-out FILE] [--obs-out FILE]
                       (--clusters N > 1 shards the MX GEMM across N simulated clusters;
                        --policy walks the whole mixed-precision model graph instead)
  mxdotp-cli reproduce [fig3|fig4|table3|formats|scaling|serving|pareto|fleet|training|all]
                       [--cores 8] [--clusters 8] [--fmt e4m3] [--cold-plans] [--policy ...]
                       [--vector-len 1|2|4|8] [--exec cycle|analytic|sampled:N]
                       [--rounding rne|stochastic[:SEED]]
                       [--trace-out FILE] [--obs-out FILE]
  mxdotp-cli serve     [--requests 16] [--batch 8] [--clusters 1] [--fabrics N]
                       [--fmt e4m3] [--mix e4m3:0.6,e2m1:0.4 | --policy PRESET|class=fmt,...]
                       [--arrival poisson[:RATE] | bursty:RATE:FACTOR:PERIOD]
                       [--slo-ticks 0] [--queue-cap 128]
                       [--sched continuous|barrier] [--artifacts DIR] [--cold-plans]
                       [--vector-len 1|2|4|8] [--exec cycle|analytic|sampled:N]
                       [--trace-out FILE] [--obs-out FILE]
                       [--machines 1] [--router affinity|rr]
  mxdotp-cli info

--fmt selects the MX element format end to end (all six OCP formats:
e5m2/e4m3 FP8, e3m2/e2m3 FP6, e2m1 FP4 at 16 lanes/issue, int8). The
'mx' kernel (alias 'mxfp8') is the format-generic hardware kernel and
accepts every format; 'fp8sw' is the FP8-only software baseline;
'fp32' ignores --fmt. 'reproduce formats' prints the format sweep on
the Fig. 4 shapes.

--policy assigns each GEMM layer of the DeiT encoder block its own
precision (DESIGN.md §13): a preset — all-fp32, all-int8, all-fp8,
fp4-ffn, all-fp4 — or a class=format list layered over the uniform
--fmt recipe (classes: qkv, scores, ctx, proj, fc1, fc2; groups: ffn,
attn, linears, all; formats: the six OCP names, fp32, and the aliases
fp8/fp6/fp4). 'reproduce pareto' sweeps the presets (plus --policy,
if given) on the DeiT-Tiny shapes and prints accuracy vs the FP32
reference against cycle-accurate fabric throughput; on other reproduce
targets (except 'training') --policy is rejected (they sweep --fmt,
not policies).

'reproduce training' runs the low-precision MX training workload
(DESIGN.md §18): it fine-tunes the DeiT block against an FP32 teacher
under the --policy precision recipe (default all-fp8) and prints one
row per point — FP32 reference, MX with RNE rounding, MX with
stochastic rounding — with the loss curve's final gap vs FP32,
cycle-accurate cycles/step for the forward+backward GEMMs, and the
analytic cost model's relative error. --rounding picks the stochastic
point's rounding spec: 'rne' (default; the stochastic point then uses
the default seed), 'stochastic' (same), or 'stochastic:SEED' to pin
the tensor-seed base. Stochastic rounding is deterministic given the
seed (same seed, same run, bit for bit) and is a training-time mode
only: every inference path (serve, the other reproduce targets)
quantizes with RNE and rejects --rounding stochastic at parse time.

serve drives the production serving engine (DESIGN.md §12) over a
synthetic open-loop arrival trace, then executes the served requests
through a real executor. --mix sets the per-request format mix
(weights are relative; default: 100 % --fmt); --policy instead makes
every request carry one per-layer policy (service time and
format-switch weight reloads are accounted per layer either way).
--arrival picks the process and its mean RATE in requests/kilotick
(1 tick = 1 µs of fabric time; RATE 0 or omitted = half the machine's
estimated capacity); bursty:4:8:2000 means mean 4/ktick arriving in 8x
bursts every 2000 ticks. --fabrics groups the clusters into
independent serving fabrics (default: one fabric per cluster; 0 is
rejected); the barrier scheduler always uses one whole-machine fabric.
--slo-ticks is the latency SLO (0 = auto: 4x the worst-case
single-request cost); --queue-cap bounds the admission queue.
'reproduce serving' prints the goodput-vs-load comparison of the two
schedulers on the same traces.

--machines N replicates the serving machine into an N-machine fleet
(DESIGN.md §17) behind a deterministic global router; every other
serve flag still shapes the per-machine engine. --router picks the
placement policy: 'affinity' (default) routes each request to the
machine with the least estimated finish cost counting the weight
reload its precision policy would pay there, so same-policy traffic
sticks to already-resident machines; 'rr' is plain round-robin.
Fleet runs cost requests with the calibrated analytic model, so
--machines N > 1 requires --exec analytic or --exec sampled:N (the
spot-checked variant audits the merged fleet population). 'reproduce
fleet' prints the fleet sweep: goodput/p99/utilization per machine
count for both routers on one mixed-policy trace.

--vector-len N sets the VMXDOTP vector length: how many MX blocks one
dot-product instruction consumes (DESIGN.md §16). 1 (default) runs the
scalar mxdotp kernel; 2/4/8 run the vector vmxdotp kernel at that VL —
bit-identical results at fewer cycles. It applies to 'simulate' (mx
kernel only), the scale-out fabric, the serving cost models and the
pareto/scaling/serving reproduce targets; the paper tables (fig3,
fig4, table3, formats) are scalar by definition and ignore it. Values
outside {1, 2, 4, 8} are rejected at parse time.

--cold-plans bypasses the compile-once/execute-many plan cache (plans,
quantized weight tiles, memoized passes, layer runs) and measures the
from-scratch path; results are bit-identical either way.

--exec picks the executor (DESIGN.md §15). 'cycle' (default) runs
everything on the cycle-accurate engine. 'analytic' costs the run with
the calibrated analytic model and never enters the cycle loop.
'sampled:N' runs analytically but calibrates against one cycle run and
deterministically spot-checks 1-in-N served requests (seeded, so the
check schedule is reproducible) on the cycle engine, exiting non-zero
if the two models diverge past the stored tolerance. Applies to
'simulate --policy', 'reproduce serving' and 'serve'; sampled:0 and
unknown modes are rejected at parse time.

--trace-out writes a Chrome/Perfetto trace-event JSON file (open it at
https://ui.perfetto.dev) with the run on one simulated timeline: serve
batches, weight-reload stalls and per-request service spans per
fabric, per-cluster shard placement, per-layer spans with MX_FMT CSR
switch markers, and a queued-requests counter track (DESIGN.md §14).
--obs-out writes the metrics registry (counters / gauges / histograms
rolled up from the same run) as pretty-printed JSON. Both artifacts
are stamped in simulated time only, so reruns are byte-identical;
host wall-clock lives under host_* keys excluded from determinism
checks. The parent directory of either path must already exist.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    /// Verbatim argument vector (for values whitespace-splitting would
    /// destroy, like an explicitly empty `--mix`).
    fn argv2(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parse_simulate() {
        let c = parse(&argv("simulate --kernel fp32 --k 128 --cores 4")).unwrap();
        assert_eq!(
            c,
            Command::Simulate {
                kernel: KernelKind::Fp32,
                m: 64,
                k: 128,
                n: 64,
                cores: 4,
                clusters: 1,
                fmt: ElemFormat::E4M3,
                seed: 42,
                cold_plans: false,
                policy: None,
                exec: ExecMode::Cycle,
                trace_out: None,
                obs_out: None,
                vector_len: 1
            }
        );
    }

    #[test]
    fn parse_vector_len() {
        // every supported VL parses on all three subcommands
        for vl in [1u8, 2, 4, 8] {
            assert!(matches!(
                parse(&argv(&format!("simulate --vector-len {vl}"))),
                Ok(Command::Simulate { vector_len, .. }) if vector_len == vl
            ));
            assert!(matches!(
                parse(&argv(&format!("serve --vector-len {vl}"))),
                Ok(Command::Serve { vector_len, .. }) if vector_len == vl
            ));
            assert!(matches!(
                parse(&argv(&format!("reproduce scaling --vector-len {vl}"))),
                Ok(Command::Reproduce { vector_len, .. }) if vector_len == vl
            ));
        }
        // omitting the flag selects the scalar kernel
        assert!(matches!(
            parse(&argv("simulate")),
            Ok(Command::Simulate { vector_len: 1, .. })
        ));
        // unsupported lengths are parse errors listing the valid set
        for bad in ["0", "3", "16", "x"] {
            let err = parse(&argv(&format!("simulate --vector-len {bad}"))).unwrap_err();
            assert!(
                err.0.contains("1, 2, 4, 8") || err.0.contains("bad value"),
                "unhelpful error for --vector-len {bad}: {err}"
            );
        }
        // the software kernels have no vector datapath
        let err = parse(&argv("simulate --kernel fp32 --vector-len 4")).unwrap_err();
        assert!(err.0.contains("only applies to the 'mx' hardware kernel"), "{err}");
        let err = parse(&argv("simulate --kernel fp8sw --vector-len 8")).unwrap_err();
        assert!(err.0.contains("fp8sw"), "{err}");
        // VL=1 on a software kernel is fine (it *is* the scalar path)
        assert!(parse(&argv("simulate --kernel fp32 --vector-len 1")).is_ok());
    }

    #[test]
    fn parse_exec_modes() {
        // default is the cycle engine on all three subcommands
        assert!(matches!(parse(&argv("serve")), Ok(Command::Serve { exec: ExecMode::Cycle, .. })));
        assert!(matches!(
            parse(&argv("reproduce serving")),
            Ok(Command::Reproduce { exec: ExecMode::Cycle, .. })
        ));
        assert!(matches!(
            parse(&argv("simulate --policy fp4-ffn")),
            Ok(Command::Simulate { exec: ExecMode::Cycle, .. })
        ));
        // explicit modes parse on all three
        assert!(matches!(
            parse(&argv("serve --exec analytic")),
            Ok(Command::Serve { exec: ExecMode::Analytic, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --exec sampled:8")),
            Ok(Command::Serve { exec: ExecMode::Sampled(8), .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce serving --exec sampled:8")),
            Ok(Command::Reproduce { exec: ExecMode::Sampled(8), .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce all --exec analytic")),
            Ok(Command::Reproduce { exec: ExecMode::Analytic, .. })
        ));
        assert!(matches!(
            parse(&argv("simulate --policy fp4-ffn --exec sampled:1")),
            Ok(Command::Simulate { exec: ExecMode::Sampled(1), .. })
        ));
        assert!(matches!(
            parse(&argv("serve --exec cycle")),
            Ok(Command::Serve { exec: ExecMode::Cycle, .. })
        ));
    }

    #[test]
    fn unknown_exec_mode_is_rejected_listing_supported_modes() {
        let err = parse(&argv("serve --exec warp")).unwrap_err();
        assert!(err.0.contains("unknown --exec mode 'warp'"), "{err}");
        for mode in ["cycle", "analytic", "sampled:N"] {
            assert!(err.0.contains(mode), "error must list '{mode}': {err}");
        }
        // sampled:0 would check nothing — rejected with guidance
        let err = parse(&argv("serve --exec sampled:0")).unwrap_err();
        assert!(err.0.contains("sampled:0"), "{err}");
        assert!(err.0.contains("at least 1"), "{err}");
        // malformed rates
        assert!(parse(&argv("serve --exec sampled:")).is_err());
        assert!(parse(&argv("serve --exec sampled:two")).is_err());
        assert!(parse(&argv("serve --exec sampled:-3")).is_err());
    }

    #[test]
    fn exec_scope_is_validated_per_subcommand() {
        // simulate without --policy is inherently a cycle run
        let err = parse(&argv("simulate --exec analytic")).unwrap_err();
        assert!(err.0.contains("--policy"), "{err}");
        assert!(parse(&argv("simulate --policy all-fp8 --exec analytic")).is_ok());
        // reproduce: only the serving comparison has an analytic model
        let err = parse(&argv("reproduce scaling --exec sampled:4")).unwrap_err();
        assert!(err.0.contains("serving"), "{err}");
        assert!(parse(&argv("reproduce fig4 --exec analytic")).is_err());
        assert!(parse(&argv("reproduce serving --exec sampled:4")).is_ok());
        assert!(parse(&argv("reproduce --exec cycle")).is_ok());
    }

    #[test]
    fn unknown_flags_are_rejected_listing_the_supported_set() {
        // a --cold-plans typo must not be silently accepted (it used to
        // be: any unknown flag parsed fine and was ignored downstream)
        let err = parse(&argv("simulate --cold-plan")).unwrap_err();
        assert!(err.0.contains("unknown flag '--cold-plan'"), "{err}");
        for flag in ["--cold-plans", "--trace-out", "--obs-out", "--kernel"] {
            assert!(err.0.contains(flag), "error must list '{flag}': {err}");
        }
        let err = parse(&argv("serve --traceout t.json")).unwrap_err();
        assert!(err.0.contains("unknown flag '--traceout'"), "{err}");
        assert!(err.0.contains("--trace-out"), "{err}");
        assert!(parse(&argv("quantize --kernel mx")).is_err());
        assert!(parse(&argv("reproduce scaling --batch 4")).is_err());
    }

    #[test]
    fn trace_and_obs_out_paths_are_validated_at_parse_time() {
        // bare filename (parent = cwd) parses fine on all three
        assert!(matches!(
            parse(&argv("serve --trace-out trace.json --obs-out m.json")),
            Ok(Command::Serve { trace_out: Some(ref t), obs_out: Some(ref o), .. })
                if t == "trace.json" && o == "m.json"
        ));
        assert!(matches!(
            parse(&argv("simulate --trace-out t.json")),
            Ok(Command::Simulate { trace_out: Some(_), obs_out: None, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce serving --obs-out m.json")),
            Ok(Command::Reproduce { obs_out: Some(_), .. })
        ));
        // a missing parent directory fails at parse time, with the path
        let err =
            parse(&argv("serve --trace-out /no/such/dir/trace.json")).unwrap_err();
        assert!(err.0.contains("--trace-out"), "{err}");
        assert!(err.0.contains("/no/such/dir"), "{err}");
        assert!(err.0.contains("does not exist"), "{err}");
        assert!(parse(&argv("simulate --obs-out /no/such/dir/m.json")).is_err());
        // an empty path is a clear error, not a write to ""
        assert!(parse(&argv2(&["serve", "--trace-out", ""])).is_err());
        // defaults stay off
        assert!(matches!(
            parse(&argv("serve")),
            Ok(Command::Serve { trace_out: None, obs_out: None, .. })
        ));
    }

    #[test]
    fn parse_policy_presets_and_custom_lists() {
        assert!(matches!(
            parse(&argv("simulate --policy fp4-ffn")),
            Ok(Command::Simulate { policy: Some(p), .. })
                if p == PrecisionPolicy::preset("fp4-ffn").unwrap()
        ));
        assert!(matches!(
            parse(&argv("reproduce pareto --policy all-fp4")),
            Ok(Command::Reproduce { ref what, policy: Some(p), .. })
                if what == "pareto" && p == PrecisionPolicy::preset("all-fp4").unwrap()
        ));
        // custom list layered over the uniform --fmt base
        assert!(matches!(
            parse(&argv("serve --fmt e5m2 --policy ffn=fp4")),
            Ok(Command::Serve { policy: Some(p), .. })
                if p == PrecisionPolicy::parse(
                    "ffn=fp4",
                    PrecisionPolicy::uniform(ElemFormat::E5M2)
                ).unwrap()
        ));
        assert!(matches!(parse(&argv("serve")), Ok(Command::Serve { policy: None, .. })));
    }

    #[test]
    fn unknown_policy_class_is_a_parse_error_listing_supported_classes() {
        let err = parse(&argv("serve --policy mlp=fp4")).unwrap_err();
        assert!(err.0.contains("unknown layer class 'mlp'"), "{err}");
        for key in ["qkv", "scores", "ctx", "proj", "fc1", "fc2", "ffn"] {
            assert!(err.0.contains(key), "error must list '{key}': {err}");
        }
        let err = parse(&argv("simulate --policy ffn=fp64")).unwrap_err();
        assert!(err.0.contains("unknown format 'fp64'"), "{err}");
        assert!(err.0.contains("e2m1"), "{err}");
    }

    #[test]
    fn reproduce_policy_only_applies_to_pareto() {
        let err = parse(&argv("reproduce serving --policy fp4-ffn")).unwrap_err();
        assert!(err.0.contains("pareto"), "{err}");
        assert!(parse(&argv("reproduce pareto --policy fp4-ffn")).is_ok());
        assert!(parse(&argv("reproduce all --policy fp4-ffn")).is_ok());
        assert!(parse(&argv("reproduce scaling --policy all-fp4")).is_err());
    }

    #[test]
    fn serve_policy_and_mix_are_mutually_exclusive() {
        let err = parse(&argv("serve --policy fp4-ffn --mix e4m3:1")).unwrap_err();
        assert!(err.0.contains("mutually exclusive"), "{err}");
        assert!(parse(&argv("serve --policy fp4-ffn")).is_ok());
        assert!(parse(&argv("serve --mix e4m3:1")).is_ok());
    }

    #[test]
    fn explicit_zero_fabrics_is_rejected_with_guidance() {
        // `--fabrics 0` used to silently mean "auto"; a machine cannot
        // have zero fabrics, so the explicit value is now rejected at
        // parse time (omitting the flag keeps the auto default).
        let err = parse(&argv("serve --fabrics 0")).unwrap_err();
        assert!(err.0.contains("--fabrics"), "{err}");
        assert!(err.0.contains("at least 1"), "{err}");
        assert!(err.0.contains("omit"), "{err}");
        assert!(matches!(
            parse(&argv("serve --clusters 8 --fabrics 4")),
            Ok(Command::Serve { fabrics: 4, .. })
        ));
        assert!(matches!(parse(&argv("serve")), Ok(Command::Serve { fabrics: 0, .. })));
    }

    #[test]
    fn empty_mix_is_rejected_with_expected_syntax() {
        let err = parse(&argv2(&["serve", "--mix", ""])).unwrap_err();
        assert!(err.0.contains("--mix"), "{err}");
        assert!(err.0.contains("fmt:weight"), "{err}");
        assert!(err.0.contains("e4m3"), "{err}");
        let err = parse(&argv2(&["serve", "--mix", "   "])).unwrap_err();
        assert!(err.0.contains("fmt:weight"), "{err}");
    }

    #[test]
    fn parse_cold_plans_flag() {
        // valueless boolean flag, anywhere among the --key value pairs
        assert!(matches!(
            parse(&argv("simulate --cold-plans --k 64")),
            Ok(Command::Simulate { cold_plans: true, k: 64, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce scaling --clusters 4 --cold-plans")),
            Ok(Command::Reproduce { cold_plans: true, clusters: 4, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --cold-plans")),
            Ok(Command::Serve { cold_plans: true, .. })
        ));
        assert!(matches!(
            parse(&argv("serve")),
            Ok(Command::Serve { cold_plans: false, .. })
        ));
    }

    #[test]
    fn parse_clusters_flag() {
        assert!(matches!(
            parse(&argv("simulate --clusters 8")),
            Ok(Command::Simulate { clusters: 8, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --clusters 4")),
            Ok(Command::Serve { clusters: 4, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce scaling --clusters 4")),
            Ok(Command::Reproduce { ref what, clusters: 4, .. }) if what == "scaling"
        ));
        // default fabric sizes: 1 for simulate/serve, 8 for reproduce
        assert!(matches!(parse(&argv("simulate")), Ok(Command::Simulate { clusters: 1, .. })));
        assert!(matches!(parse(&argv("reproduce")), Ok(Command::Reproduce { clusters: 8, .. })));
        assert!(parse(&argv("simulate --clusters 0")).is_err());
        assert!(parse(&argv("serve --clusters 0")).is_err());
        assert!(parse(&argv("reproduce scaling --clusters 0")).is_err());
    }

    #[test]
    fn parse_reproduce_variants() {
        assert!(matches!(parse(&argv("reproduce")), Ok(Command::Reproduce { what, .. }) if what == "all"));
        assert!(matches!(parse(&argv("reproduce fig4 --cores 2")), Ok(Command::Reproduce { what, cores: 2, .. }) if what == "fig4"));
        assert!(parse(&argv("reproduce fig9")).is_err());
    }

    #[test]
    fn parse_errors() {
        assert!(parse(&argv("simulate --kernel quantum")).is_err());
        assert!(parse(&argv("simulate --k")).is_err());
        assert!(parse(&argv("frobnicate")).is_err());
        assert!(parse(&argv("quantize --fmt fp64")).is_err());
    }

    #[test]
    fn kernel_format_mismatch_is_a_parse_error_listing_supported_formats() {
        // fp8sw + a non-FP8 format must fail at parse time, not on a
        // deep plan assert — and the message must list what IS valid.
        let err = parse(&argv("simulate --kernel fp8sw --fmt e2m1")).unwrap_err();
        assert!(err.0.contains("fp8sw"), "{err}");
        assert!(err.0.contains("e4m3") && err.0.contains("e5m2"), "{err}");
        assert!(parse(&argv("simulate --kernel fp8sw --fmt int8")).is_err());
        assert!(parse(&argv("simulate --kernel fp8sw --fmt e5m2")).is_ok());
        // the hw kernel and fp32 take every format
        for fmt in ElemFormat::ALL {
            assert!(
                matches!(
                    parse(&argv(&format!("simulate --kernel mx --fmt {fmt}"))),
                    Ok(Command::Simulate { kernel: KernelKind::Mx(f), .. }) if f == fmt
                ),
                "{fmt}"
            );
            assert!(parse(&argv(&format!("simulate --kernel fp32 --fmt {fmt}"))).is_ok());
        }
        // flag order must not matter (fmt parsed before kernel check)
        assert!(parse(&argv("simulate --fmt e2m1 --kernel fp8sw")).is_err());
    }

    #[test]
    fn default_and_alias_kernels_follow_fmt() {
        // no --kernel: the hw kernel at the requested format
        assert!(matches!(
            parse(&argv("simulate --fmt e2m1")),
            Ok(Command::Simulate { kernel: KernelKind::Mx(ElemFormat::E2M1), .. })
        ));
        // 'mxfp8' stays as a compatibility alias for 'mx'
        assert!(matches!(
            parse(&argv("simulate --kernel mxfp8")),
            Ok(Command::Simulate { kernel: KernelKind::Mx(ElemFormat::E4M3), .. })
        ));
    }

    #[test]
    fn serve_rejects_zero_batch_at_parse_time() {
        // A zero batch makes the batcher wait forever; reject it like
        // --clusters 0 instead of hanging at runtime.
        let err = parse(&argv("serve --batch 0")).unwrap_err();
        assert!(err.0.contains("--batch"), "{err}");
        assert!(err.0.contains("at least 1"), "{err}");
        assert!(matches!(parse(&argv("serve --batch 1")), Ok(Command::Serve { batch: 1, .. })));
    }

    #[test]
    fn parse_serve_mix_arrival_slo_and_sched() {
        let c = parse(&argv(
            "serve --mix e4m3:0.6,e2m1:0.4 --arrival bursty:4:8:2000 --slo-ticks 9000 \
             --queue-cap 64 --fabrics 2 --clusters 8 --sched barrier",
        ))
        .unwrap();
        match c {
            Command::Serve {
                mix, arrival, rate_per_ktick, slo_ticks, queue_cap, fabrics, clusters, sched, ..
            } => {
                assert_eq!(mix, vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)]);
                assert_eq!(
                    arrival,
                    crate::workload::arrivals::ArrivalKind::Bursty {
                        burst_factor: 8.0,
                        period_ticks: 2000
                    }
                );
                assert_eq!(rate_per_ktick, 4.0);
                assert_eq!(slo_ticks, 9000);
                assert_eq!(queue_cap, 64);
                assert_eq!((fabrics, clusters), (2, 8));
                assert_eq!(sched, crate::serve::SchedulerKind::Barrier);
            }
            other => panic!("parsed {other:?}"),
        }
        // defaults: continuous scheduler, auto rate, single-format mix
        assert!(matches!(
            parse(&argv("serve --fmt e2m1")),
            Ok(Command::Serve {
                sched: crate::serve::SchedulerKind::Continuous,
                rate_per_ktick: r,
                ref mix,
                ..
            }) if r == 0.0 && mix == &vec![(ElemFormat::E2M1, 1.0)]
        ));
        assert!(matches!(
            parse(&argv("serve --arrival poisson:12")),
            Ok(Command::Serve { rate_per_ktick: r, .. }) if r == 12.0
        ));
    }

    #[test]
    fn serve_flag_validation_errors() {
        // malformed mixes
        assert!(parse(&argv("serve --mix e4m3")).is_err());
        assert!(parse(&argv("serve --mix fp64:1.0")).is_err());
        assert!(parse(&argv("serve --mix e4m3:0")).is_err());
        // malformed arrivals
        assert!(parse(&argv("serve --arrival warp")).is_err());
        assert!(parse(&argv("serve --arrival bursty:4")).is_err());
        assert!(parse(&argv("serve --arrival bursty:4:0.5:100")).is_err());
        // fabric / queue / scheduler validation
        assert!(parse(&argv("serve --clusters 8 --fabrics 3")).is_err());
        assert!(parse(&argv("serve --clusters 8 --fabrics 16")).is_err());
        assert!(parse(&argv("serve --queue-cap 0")).is_err());
        assert!(parse(&argv("serve --sched sometimes")).is_err());
    }

    #[test]
    fn parse_reproduce_serving_target() {
        assert!(matches!(
            parse(&argv("reproduce serving --clusters 8")),
            Ok(Command::Reproduce { ref what, clusters: 8, .. }) if what == "serving"
        ));
    }

    #[test]
    fn parse_serve_fleet_flags() {
        // defaults: a one-machine "fleet" behind the affinity router
        assert!(matches!(
            parse(&argv("serve")),
            Ok(Command::Serve { machines: 1, router: RouterKind::Affinity, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --machines 4 --router rr --exec analytic")),
            Ok(Command::Serve { machines: 4, router: RouterKind::RoundRobin, .. })
        ));
        // 'round-robin' is accepted as an alias for 'rr'
        assert!(matches!(
            parse(&argv("serve --machines 2 --router round-robin --exec sampled:8")),
            Ok(Command::Serve { machines: 2, router: RouterKind::RoundRobin, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --machines 3 --router affinity --exec analytic")),
            Ok(Command::Serve { machines: 3, router: RouterKind::Affinity, .. })
        ));
        // --router alone is fine on one machine (it routes everything
        // to machine 0 either way)
        assert!(parse(&argv("serve --router rr")).is_ok());
    }

    #[test]
    fn serve_fleet_flag_validation_errors() {
        // an empty fleet has nowhere to route
        let err = parse(&argv("serve --machines 0")).unwrap_err();
        assert!(err.0.contains("--machines"), "{err}");
        assert!(err.0.contains("at least 1"), "{err}");
        // fleets cost requests analytically; the default cycle executor
        // is rejected with guidance toward analytic/sampled
        let err = parse(&argv("serve --machines 2")).unwrap_err();
        assert!(err.0.contains("analytic"), "{err}");
        assert!(err.0.contains("sampled"), "{err}");
        assert!(parse(&argv("serve --machines 2 --exec cycle")).is_err());
        // unknown routers list the supported set
        let err = parse(&argv("serve --router warp --exec analytic")).unwrap_err();
        assert!(err.0.contains("unknown router 'warp'"), "{err}");
        assert!(err.0.contains("affinity") && err.0.contains("rr"), "{err}");
    }

    #[test]
    fn parse_reproduce_fleet_target() {
        assert!(matches!(
            parse(&argv("reproduce fleet")),
            Ok(Command::Reproduce { ref what, exec: ExecMode::Cycle, .. }) if what == "fleet"
        ));
        // the fleet sweep accepts the analytic/sampled executors
        assert!(matches!(
            parse(&argv("reproduce fleet --exec sampled:64")),
            Ok(Command::Reproduce { ref what, exec: ExecMode::Sampled(64), .. })
                if what == "fleet"
        ));
        assert!(parse(&argv("reproduce fleet --exec analytic")).is_ok());
        // and shows up in the unknown-target error listing
        let err = parse(&argv("reproduce fig9")).unwrap_err();
        assert!(err.0.contains("fleet"), "{err}");
    }

    #[test]
    fn parse_reproduce_training_target_and_rounding_modes() {
        // default: RNE quantization, all-fp8 chosen downstream
        assert!(matches!(
            parse(&argv("reproduce training")),
            Ok(Command::Reproduce { ref what, rounding: Rounding::Rne, policy: None, .. })
                if what == "training"
        ));
        // explicit modes parse, with and without a pinned seed
        assert!(matches!(
            parse(&argv("reproduce training --rounding rne")),
            Ok(Command::Reproduce { rounding: Rounding::Rne, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce training --rounding stochastic")),
            Ok(Command::Reproduce {
                rounding: Rounding::Stochastic(Rounding::DEFAULT_SEED),
                ..
            })
        ));
        assert!(matches!(
            parse(&argv("reproduce training --rounding stochastic:7")),
            Ok(Command::Reproduce { rounding: Rounding::Stochastic(7), .. })
        ));
        // training consumes a policy (the MX recipe under test)
        assert!(parse(&argv("reproduce training --policy all-fp4")).is_ok());
        // unknown modes and malformed seeds list the supported values
        let err = parse(&argv("reproduce training --rounding nearest")).unwrap_err();
        assert!(err.0.contains("unknown rounding mode 'nearest'"), "{err}");
        for mode in ["rne", "stochastic", "stochastic:SEED"] {
            assert!(err.0.contains(mode), "error must list '{mode}': {err}");
        }
        assert!(parse(&argv("reproduce training --rounding stochastic:abc")).is_err());
        assert!(parse(&argv("reproduce training --rounding stochastic:-1")).is_err());
        // and the target shows up in the unknown-target error listing
        let err = parse(&argv("reproduce fig9")).unwrap_err();
        assert!(err.0.contains("training"), "{err}");
    }

    #[test]
    fn stochastic_rounding_is_rejected_on_inference_paths() {
        // serving is RNE-only; the error points at the training
        // workload and its design section
        let err = parse(&argv("serve --rounding stochastic")).unwrap_err();
        assert!(err.0.contains("serving"), "{err}");
        assert!(err.0.contains("training"), "{err}");
        assert!(err.0.contains("DESIGN.md §18"), "{err}");
        // the explicit spelling of the default is accepted
        assert!(parse(&argv("serve --rounding rne")).is_ok());
        // inference reproduce targets are RNE-only too
        let err = parse(&argv("reproduce pareto --rounding stochastic:9")).unwrap_err();
        assert!(err.0.contains("training"), "{err}");
        assert!(err.0.contains("§18"), "{err}");
        assert!(parse(&argv("reproduce all --rounding stochastic")).is_err());
        assert!(parse(&argv("reproduce scaling --rounding rne")).is_ok());
        // simulate has no --rounding flag at all
        let err = parse(&argv("simulate --rounding stochastic")).unwrap_err();
        assert!(err.0.contains("unknown flag"), "{err}");
    }

    #[test]
    fn parse_serve_fmt_and_reproduce_formats() {
        assert!(matches!(
            parse(&argv("serve --fmt int8")),
            Ok(Command::Serve { fmt: ElemFormat::Int8, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce formats --fmt e2m1")),
            Ok(Command::Reproduce { ref what, fmt: ElemFormat::E2M1, .. }) if what == "formats"
        ));
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(parse(&[]).unwrap(), Command::Help);
    }
}
