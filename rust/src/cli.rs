//! Hand-rolled CLI (the offline environment has no clap): subcommand
//! parsing for `mxdotp-cli`.
//!
//! ```text
//! mxdotp-cli quantize  --fmt e4m3 --block 32 --n 8 [--seed S]
//! mxdotp-cli simulate  --kernel mx|fp32|fp8sw --m 64 --k 256 --n 64
//!                      [--cores 8] [--fmt e5m2|e4m3|e3m2|e2m3|e2m1|int8] [--seed S]
//! mxdotp-cli reproduce fig3|fig4|table3|formats|scaling|all [--cores 8] [--fmt e4m3]
//! mxdotp-cli serve     [--requests 16] [--batch 8] [--fmt e4m3] [--artifacts DIR]
//! mxdotp-cli info
//! ```
//!
//! Kernel/format compatibility is validated at parse time
//! ([`kernel_for`]): the `mx` hardware kernel takes every OCP element
//! format, `fp8sw` is FP8-only, `fp32` ignores the format.

use crate::formats::ElemFormat;
use crate::kernels::KernelKind;
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Quantize { fmt: ElemFormat, block: usize, n: usize, seed: u64 },
    Simulate { kernel: KernelKind, m: usize, k: usize, n: usize, cores: usize, clusters: usize, fmt: ElemFormat, seed: u64, cold_plans: bool },
    Reproduce { what: String, cores: usize, clusters: usize, fmt: ElemFormat, cold_plans: bool },
    Serve { requests: usize, batch: usize, clusters: usize, fmt: ElemFormat, artifacts: String, cold_plans: bool },
    Info,
    Help,
}

/// Resolve a kernel name + element format at parse/dispatch time,
/// rejecting unsupported combinations with the per-kernel format list
/// (instead of dying later on a deep plan assert).
pub fn kernel_for(name: &str, fmt: ElemFormat) -> Result<KernelKind, CliError> {
    let kind = match name {
        "fp32" => KernelKind::Fp32,
        "fp8sw" | "fp8-to-fp32" => KernelKind::Fp8ToFp32,
        "mx" | "mxfp8" => KernelKind::Mx(fmt),
        other => return Err(CliError(format!("unknown kernel '{other}' (mx|fp32|fp8sw)"))),
    };
    if !kind.supported_fmts().contains(&fmt) {
        let supported: Vec<&str> =
            kind.supported_fmts().iter().map(|f| f.name()).collect();
        return Err(CliError(format!(
            "kernel '{name}' does not support --fmt {fmt}; supported formats: {}",
            supported.join(", ")
        )));
    }
    Ok(kind)
}

/// Parse error with a user-facing message.
#[derive(Clone, Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Valueless boolean flags (present = true).
const BOOL_FLAGS: [&str; 1] = ["cold-plans"];

/// Split `--key value` pairs (plus valueless boolean flags) after the
/// subcommand.
fn flags(args: &[String]) -> Result<HashMap<String, String>, CliError> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            return Err(CliError(format!("unexpected argument '{k}' (flags are --key value)")));
        }
        let name = k.trim_start_matches("--");
        if BOOL_FLAGS.contains(&name) {
            map.insert(name.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let v = args
            .get(i + 1)
            .ok_or_else(|| CliError(format!("flag '{k}' needs a value")))?;
        map.insert(name.to_string(), v.clone());
        i += 2;
    }
    Ok(map)
}

/// `--cold-plans`: bypass the plan/pass caches (cold-path measurement).
fn get_cold_plans(f: &HashMap<String, String>) -> bool {
    f.contains_key("cold-plans")
}

fn get_parse<T: std::str::FromStr>(
    f: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T, CliError> {
    match f.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| CliError(format!("bad value for --{key}: '{v}'"))),
    }
}

/// `--clusters N`: size of the simulated cluster fabric.
fn get_clusters(f: &HashMap<String, String>, default: usize) -> Result<usize, CliError> {
    let clusters: usize = get_parse(f, "clusters", default)?;
    if clusters == 0 {
        return Err(CliError("--clusters must be at least 1".into()));
    }
    Ok(clusters)
}

fn get_fmt(f: &HashMap<String, String>) -> Result<ElemFormat, CliError> {
    match f.get("fmt") {
        None => Ok(ElemFormat::E4M3),
        Some(v) => {
            ElemFormat::parse(v).ok_or_else(|| CliError(format!("unknown format '{v}'")))
        }
    }
}

/// Parse a full argument vector (without argv[0]).
pub fn parse(args: &[String]) -> Result<Command, CliError> {
    let Some(cmd) = args.first() else {
        return Ok(Command::Help);
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => Ok(Command::Help),
        "info" => Ok(Command::Info),
        "quantize" => {
            let f = flags(rest)?;
            Ok(Command::Quantize {
                fmt: get_fmt(&f)?,
                block: get_parse(&f, "block", 32)?,
                n: get_parse(&f, "n", 8)?,
                seed: get_parse(&f, "seed", 42)?,
            })
        }
        "simulate" => {
            let f = flags(rest)?;
            let fmt = get_fmt(&f)?;
            let kernel = kernel_for(f.get("kernel").map(String::as_str).unwrap_or("mx"), fmt)?;
            Ok(Command::Simulate {
                kernel,
                m: get_parse(&f, "m", 64)?,
                k: get_parse(&f, "k", 256)?,
                n: get_parse(&f, "n", 64)?,
                cores: get_parse(&f, "cores", 8)?,
                clusters: get_clusters(&f, 1)?,
                fmt,
                seed: get_parse(&f, "seed", 42)?,
                cold_plans: get_cold_plans(&f),
            })
        }
        "reproduce" => {
            let what = rest
                .first()
                .filter(|w| !w.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            if !["fig3", "fig4", "table3", "formats", "scaling", "all"].contains(&what.as_str()) {
                return Err(CliError(format!(
                    "unknown target '{what}' (expected fig3|fig4|table3|formats|scaling|all)"
                )));
            }
            let skip = usize::from(!rest.is_empty() && !rest[0].starts_with("--"));
            let f = flags(&rest[skip..])?;
            Ok(Command::Reproduce {
                what,
                cores: get_parse(&f, "cores", 8)?,
                clusters: get_clusters(&f, 8)?,
                fmt: get_fmt(&f)?,
                cold_plans: get_cold_plans(&f),
            })
        }
        "serve" => {
            let f = flags(rest)?;
            Ok(Command::Serve {
                requests: get_parse(&f, "requests", 16)?,
                batch: get_parse(&f, "batch", 8)?,
                clusters: get_clusters(&f, 1)?,
                fmt: get_fmt(&f)?,
                artifacts: f.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
                cold_plans: get_cold_plans(&f),
            })
        }
        other => Err(CliError(format!("unknown subcommand '{other}' (try 'help')"))),
    }
}

pub const USAGE: &str = "\
mxdotp-cli — MXDOTP paper reproduction driver

USAGE:
  mxdotp-cli quantize  [--fmt e4m3|e5m2|e3m2|e2m3|e2m1|int8] [--block 32] [--n 8] [--seed S]
  mxdotp-cli simulate  [--kernel mx|fp32|fp8sw] [--m 64] [--k 256] [--n 64]
                       [--cores 8] [--clusters 1] [--fmt e4m3] [--seed S] [--cold-plans]
                       (--clusters N > 1 shards the MX GEMM across N simulated clusters)
  mxdotp-cli reproduce [fig3|fig4|table3|formats|scaling|all] [--cores 8] [--clusters 8]
                       [--fmt e4m3] [--cold-plans]
  mxdotp-cli serve     [--requests 16] [--batch 8] [--clusters 1] [--fmt e4m3]
                       [--artifacts DIR] [--cold-plans]
  mxdotp-cli info

--fmt selects the MX element format end to end (all six OCP formats:
e5m2/e4m3 FP8, e3m2/e2m3 FP6, e2m1 FP4 at 16 lanes/issue, int8). The
'mx' kernel (alias 'mxfp8') is the format-generic hardware kernel and
accepts every format; 'fp8sw' is the FP8-only software baseline;
'fp32' ignores --fmt. 'reproduce formats' prints the format sweep on
the Fig. 4 shapes.

--cold-plans bypasses the compile-once/execute-many plan cache (plans,
quantized weight tiles, memoized passes) and measures the from-scratch
path; results are bit-identical either way.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parse_simulate() {
        let c = parse(&argv("simulate --kernel fp32 --k 128 --cores 4")).unwrap();
        assert_eq!(
            c,
            Command::Simulate {
                kernel: KernelKind::Fp32,
                m: 64,
                k: 128,
                n: 64,
                cores: 4,
                clusters: 1,
                fmt: ElemFormat::E4M3,
                seed: 42,
                cold_plans: false
            }
        );
    }

    #[test]
    fn parse_cold_plans_flag() {
        // valueless boolean flag, anywhere among the --key value pairs
        assert!(matches!(
            parse(&argv("simulate --cold-plans --k 64")),
            Ok(Command::Simulate { cold_plans: true, k: 64, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce scaling --clusters 4 --cold-plans")),
            Ok(Command::Reproduce { cold_plans: true, clusters: 4, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --cold-plans")),
            Ok(Command::Serve { cold_plans: true, .. })
        ));
        assert!(matches!(
            parse(&argv("serve")),
            Ok(Command::Serve { cold_plans: false, .. })
        ));
    }

    #[test]
    fn parse_clusters_flag() {
        assert!(matches!(
            parse(&argv("simulate --clusters 8")),
            Ok(Command::Simulate { clusters: 8, .. })
        ));
        assert!(matches!(
            parse(&argv("serve --clusters 4")),
            Ok(Command::Serve { clusters: 4, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce scaling --clusters 4")),
            Ok(Command::Reproduce { ref what, clusters: 4, .. }) if what == "scaling"
        ));
        // default fabric sizes: 1 for simulate/serve, 8 for reproduce
        assert!(matches!(parse(&argv("simulate")), Ok(Command::Simulate { clusters: 1, .. })));
        assert!(matches!(parse(&argv("reproduce")), Ok(Command::Reproduce { clusters: 8, .. })));
        assert!(parse(&argv("simulate --clusters 0")).is_err());
        assert!(parse(&argv("serve --clusters 0")).is_err());
        assert!(parse(&argv("reproduce scaling --clusters 0")).is_err());
    }

    #[test]
    fn parse_reproduce_variants() {
        assert!(matches!(parse(&argv("reproduce")), Ok(Command::Reproduce { what, .. }) if what == "all"));
        assert!(matches!(parse(&argv("reproduce fig4 --cores 2")), Ok(Command::Reproduce { what, cores: 2, .. }) if what == "fig4"));
        assert!(parse(&argv("reproduce fig9")).is_err());
    }

    #[test]
    fn parse_errors() {
        assert!(parse(&argv("simulate --kernel quantum")).is_err());
        assert!(parse(&argv("simulate --k")).is_err());
        assert!(parse(&argv("frobnicate")).is_err());
        assert!(parse(&argv("quantize --fmt fp64")).is_err());
    }

    #[test]
    fn kernel_format_mismatch_is_a_parse_error_listing_supported_formats() {
        // fp8sw + a non-FP8 format must fail at parse time, not on a
        // deep plan assert — and the message must list what IS valid.
        let err = parse(&argv("simulate --kernel fp8sw --fmt e2m1")).unwrap_err();
        assert!(err.0.contains("fp8sw"), "{err}");
        assert!(err.0.contains("e4m3") && err.0.contains("e5m2"), "{err}");
        assert!(parse(&argv("simulate --kernel fp8sw --fmt int8")).is_err());
        assert!(parse(&argv("simulate --kernel fp8sw --fmt e5m2")).is_ok());
        // the hw kernel and fp32 take every format
        for fmt in ElemFormat::ALL {
            assert!(
                matches!(
                    parse(&argv(&format!("simulate --kernel mx --fmt {fmt}"))),
                    Ok(Command::Simulate { kernel: KernelKind::Mx(f), .. }) if f == fmt
                ),
                "{fmt}"
            );
            assert!(parse(&argv(&format!("simulate --kernel fp32 --fmt {fmt}"))).is_ok());
        }
        // flag order must not matter (fmt parsed before kernel check)
        assert!(parse(&argv("simulate --fmt e2m1 --kernel fp8sw")).is_err());
    }

    #[test]
    fn default_and_alias_kernels_follow_fmt() {
        // no --kernel: the hw kernel at the requested format
        assert!(matches!(
            parse(&argv("simulate --fmt e2m1")),
            Ok(Command::Simulate { kernel: KernelKind::Mx(ElemFormat::E2M1), .. })
        ));
        // 'mxfp8' stays as a compatibility alias for 'mx'
        assert!(matches!(
            parse(&argv("simulate --kernel mxfp8")),
            Ok(Command::Simulate { kernel: KernelKind::Mx(ElemFormat::E4M3), .. })
        ));
    }

    #[test]
    fn parse_serve_fmt_and_reproduce_formats() {
        assert!(matches!(
            parse(&argv("serve --fmt int8")),
            Ok(Command::Serve { fmt: ElemFormat::Int8, .. })
        ));
        assert!(matches!(
            parse(&argv("reproduce formats --fmt e2m1")),
            Ok(Command::Reproduce { ref what, fmt: ElemFormat::E2M1, .. }) if what == "formats"
        ));
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(parse(&[]).unwrap(), Command::Help);
    }
}
