//! # mxdotp — full-system reproduction of the MXDOTP paper
//!
//! *MXDOTP: A RISC-V ISA Extension for Enabling Microscaling (MX)
//! Floating-Point Dot Products* (İslamoğlu et al., CS.AR 2025).
//!
//! The crate contains every system the paper builds on (see DESIGN.md):
//!
//! * [`formats`] — the OCP Microscaling v1.0 format library: FP8
//!   (E5M2/E4M3), FP6 (E3M2/E2M3), FP4 (E2M1), INT8 elements, E8M0
//!   block scales, quantization under RNE or deterministic-seeded
//!   stochastic rounding (DESIGN.md §18), and the spec's Dot /
//!   DotGeneral.
//! * [`dotp`] — a bit-accurate model of the MXDOTP dot-product-
//!   accumulate datapath (95-bit fixed-point early accumulation,
//!   anchor 34, single RNE round to FP32), format-generic over the
//!   whole OCP element family (8 × FP8/FP6/INT8 or 16 × FP4 lanes per
//!   issue, DESIGN.md §11), plus the baseline units the paper compares
//!   against in Table III.
//! * [`snitch`] — a cycle-accurate simulator of the 8-core Snitch
//!   cluster: RV32IMAFD subset + FREP + SSR + the `mxdotp` instruction,
//!   32-bank shared L1 SPM behind a logarithmic interconnect, DMA.
//! * [`kernels`] — the matrix-multiplication kernels of Fig. 2
//!   (FP32, FP8-to-FP32 software MX, and the format-generic MX
//!   hardware kernel) as instruction-
//!   stream builders, split into a compile-once plan layer
//!   (`kernels::plan`: shape-keyed SPM layouts + shared per-core
//!   programs + worst-case cycle bounds, with a warm `PlanCache` for
//!   plans, quantized B tiles and memoized passes) and an
//!   execute-many half that runs against reset, long-lived clusters.
//! * [`energy`] — GE-level area accounting and per-op energy models
//!   calibrated to the paper's 12 nm FinFET implementation numbers.
//! * [`scaleout`] — the multi-cluster scale-out engine: MX-block-aware
//!   tile partitioning, a pool of N worker threads each owning one
//!   persistent cluster simulator (work stealing included), warm plan
//!   reuse across passes/shards/requests, and the fabric aggregation
//!   model (wall-clock = max over clusters, energy = sum).
//! * [`runtime`] — PJRT CPU runtime loading the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); Python is never on this path.
//! * [`coordinator`] — the executor layer: the `ModelExecutor` trait
//!   (single-request and batch-splice entry points), the PJRT and
//!   in-process MX executors, and the seed-era barrier coordinator the
//!   serving engine is benchmarked against.
//! * [`model`] — the per-layer mixed-precision model graph
//!   (DESIGN.md §13): the typed encoder-block layer graph, precision
//!   policies mapping each layer class to an element format (presets
//!   `all-fp8`, `fp4-ffn`, `all-fp4`, ...), the graph-walking host
//!   executor (bit-identical to the single-format path for uniform
//!   policies) and the cycle-accurate per-layer policy runner behind
//!   the accuracy/throughput Pareto sweep — plus the training side
//!   (DESIGN.md §18): backward GEMM nodes (dX = dY·Wᵀ, dW = Xᵀ·dY),
//!   the deterministic teacher–student fine-tuning loop, and the
//!   probe-calibrated analytic cycles/step cross-check.
//! * [`serve`] — the production serving engine (DESIGN.md §12):
//!   per-(format, priority) request queues, admission control with
//!   bounded backpressure and reject reasons, continuous batching with
//!   in-flight splice, a multi-fabric scheduler placing batches on
//!   least-loaded cluster groups, and p50/p95/p99 latency accounting
//!   in simulated ticks.
//! * [`workload`] — DeiT-Tiny-shaped synthetic workload generation,
//!   the analytic cost models, and the open-loop arrival-trace
//!   generators (Poisson / bursty, per-format mix).
//! * [`obs`] — the deterministic observability layer (DESIGN.md §14):
//!   sim-time span tracing across the serve → fabric → layer → kernel
//!   hierarchy, the typed metrics registry behind `OBS_metrics.json`,
//!   the Chrome/Perfetto trace exporter behind `--trace-out`, and the
//!   host-side simulator-speed profile surfaced by the hotpath bench.
//! * [`fleet`] — fleet-scale serving (DESIGN.md §17): N replicated
//!   machines behind a deterministic policy-affinity router, per-tenant
//!   fair-share admission, hysteresis autoscaling in simulated ticks,
//!   and the merged-population metrics rollup behind `BENCH_fleet.json`.

#![warn(missing_docs)]

pub mod dotp;
pub mod formats;
pub mod energy;
pub mod kernels;
pub mod cli;
pub mod coordinator;
pub mod fleet;
pub mod model;
pub mod obs;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod scaleout;
pub mod serve;
pub mod snitch;
pub mod workload;

pub use formats::{ElemFormat, MxMatrix, MxVector};
