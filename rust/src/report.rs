//! Paper-style report generation: every table and figure of the
//! evaluation section, regenerated from the models and the simulator.
//! Shared by the benches, the CLI (`mxdotp-cli reproduce ...`) and the
//! examples, so the numbers in all three are identical by construction.

use crate::dotp::baselines::table3_rows;
use crate::energy::constants as k;
use crate::energy::{AreaModel, EnergyModel};
use crate::fleet::{simulate_fleet, FleetConfig, RouterKind};
use crate::formats::ElemFormat;
use crate::formats::Rounding;
use crate::kernels::{layout, run_mm, KernelKind, MmProblem, MmRun};
use crate::model::hw::analytic_training_cycles;
use crate::model::{
    policy_hw_run, training_hw_run, GraphExecutor, ModelGraph, PolicyHwRun, PrecisionPolicy,
    TrainConfig, Trainer, TrainingHwRun,
};
use crate::rng::XorShift;
use crate::scaleout::{sharded_mm, ScaleoutConfig};
use crate::serve::{self, SchedulerKind, ServeConfig};
use crate::workload::arrivals::{
    assign_policy_classes, generate_trace, Arrival, ArrivalKind, ArrivalSpec,
};
use crate::workload::{generate_input, generate_params, DeitConfig};

/// The Fig. 4 inner-dimension sweep (block size 32 bounds K below).
pub const FIG4_K_SWEEP: [usize; 4] = [32, 64, 128, 256];

/// One Fig. 4 data point.
#[derive(Clone, Debug)]
pub struct Fig4Point {
    /// Inner dimension of the sweep point.
    pub k: usize,
    /// Kernel measured.
    pub kind: KernelKind,
    /// Achieved throughput (GFLOPS).
    pub gflops: f64,
    /// Energy efficiency (GFLOPS/W).
    pub gflops_per_w: f64,
    /// Fraction of the kernel's ideal throughput.
    pub utilization: f64,
    /// Simulated cycles of the run.
    pub cycles: u64,
    /// Average power (mW).
    pub power_mw: f64,
}

/// Run the full Fig. 4 sweep (both subfigures) for one element format.
/// The FP8-to-FP32 software baseline only exists for the FP8 formats;
/// for the other element formats its column is absent (like FP32 at
/// K=256).
pub fn fig4_sweep(fmt: ElemFormat, num_cores: usize, seed: u64) -> Vec<Fig4Point> {
    let em = EnergyModel;
    let mut points = Vec::new();
    for &kdim in &FIG4_K_SWEEP {
        let p = MmProblem::fig4(kdim, fmt);
        let mut rng = XorShift::new(seed ^ kdim as u64);
        let a = rng.normal_vec(p.m * p.k, 1.0);
        let b = rng.normal_vec(p.k * p.n, 1.0);
        let mut kinds = vec![KernelKind::Mx(fmt)];
        if KernelKind::Fp8ToFp32.supported_fmts().contains(&fmt) {
            kinds.insert(0, KernelKind::Fp8ToFp32);
        }
        // the paper's footnote: FP32 does not fit into L1 at K=256
        if layout::fp32_footprint(&p) <= crate::snitch::SPM_BYTES {
            kinds.insert(0, KernelKind::Fp32);
        }
        for kind in kinds {
            let run = run_mm(kind, p, &a, &b, num_cores);
            let with_mx = matches!(kind, KernelKind::Mx(_));
            let power = em.power(&run.perf, run.freq_ghz, with_mx);
            points.push(Fig4Point {
                k: kdim,
                kind,
                gflops: run.gflops(),
                gflops_per_w: em.gflops_per_w(&run.perf, p.flops(), run.freq_ghz, with_mx),
                utilization: run.utilization(),
                cycles: run.perf.cycles,
                power_mw: power.total_mw,
            });
        }
    }
    points
}

/// Headline metrics derived from a Fig. 4 sweep (§IV-C's claims).
#[derive(Clone, Copy, Debug, Default)]
pub struct Headline {
    /// Best MX throughput across the sweep (GFLOPS).
    pub peak_gflops: f64,
    /// Best MX energy efficiency (GFLOPS/W).
    pub peak_gflops_per_w: f64,
    /// Best MX utilization.
    pub peak_utilization: f64,
    /// (min, max) MX speedup over FP32 across K.
    pub speedup_vs_fp32: (f64, f64),
    /// (min, max) MX speedup over the software baseline.
    pub speedup_vs_sw: (f64, f64),
    /// (min, max) efficiency ratio vs FP32.
    pub eff_vs_fp32: (f64, f64),
    /// (min, max) efficiency ratio vs the software baseline.
    pub eff_vs_sw: (f64, f64),
}

/// Compute the §IV-C headline ranges from sweep points.
pub fn headline(points: &[Fig4Point]) -> Headline {
    let mut h = Headline {
        speedup_vs_fp32: (f64::MAX, 0.0),
        speedup_vs_sw: (f64::MAX, 0.0),
        eff_vs_fp32: (f64::MAX, 0.0),
        eff_vs_sw: (f64::MAX, 0.0),
        ..Default::default()
    };
    for &kdim in &FIG4_K_SWEEP {
        let get = |kind: KernelKind| points.iter().find(|p| p.k == kdim && p.kind == kind);
        let Some(mx) = points.iter().find(|p| p.k == kdim && matches!(p.kind, KernelKind::Mx(_)))
        else {
            continue;
        };
        h.peak_gflops = h.peak_gflops.max(mx.gflops);
        h.peak_gflops_per_w = h.peak_gflops_per_w.max(mx.gflops_per_w);
        h.peak_utilization = h.peak_utilization.max(mx.utilization);
        if let Some(f) = get(KernelKind::Fp32) {
            let s = mx.gflops / f.gflops;
            h.speedup_vs_fp32 = (h.speedup_vs_fp32.0.min(s), h.speedup_vs_fp32.1.max(s));
            let e = mx.gflops_per_w / f.gflops_per_w;
            h.eff_vs_fp32 = (h.eff_vs_fp32.0.min(e), h.eff_vs_fp32.1.max(e));
        }
        if let Some(sw) = get(KernelKind::Fp8ToFp32) {
            let s = mx.gflops / sw.gflops;
            h.speedup_vs_sw = (h.speedup_vs_sw.0.min(s), h.speedup_vs_sw.1.max(s));
            let e = mx.gflops_per_w / sw.gflops_per_w;
            h.eff_vs_sw = (h.eff_vs_sw.0.min(e), h.eff_vs_sw.1.max(e));
        }
    }
    h
}

/// Render Fig. 4 (both subfigures) as text.
pub fn render_fig4(points: &[Fig4Point], fmt: ElemFormat) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Fig. 4 — M=N=64, inner dimension sweep, 8 cores @ 1 GHz, {fmt}\n\
         (paper: MXFP8 up to 102 GFLOPS / 356 GFLOPS/W; FP32 absent at K=256)\n\n"
    ));
    s.push_str("(a) Throughput [GFLOPS]\n");
    s.push_str("  K      FP32   FP8-to-FP32   MX-HW    (MX util)\n");
    for &kdim in &FIG4_K_SWEEP {
        let cell = |kind| {
            points
                .iter()
                .find(|p| p.k == kdim && p.kind == kind)
                .map(|p| format!("{:6.1}", p.gflops))
                .unwrap_or_else(|| "     —".into())
        };
        let util = points
            .iter()
            .find(|p| p.k == kdim && p.kind == KernelKind::Mx(fmt))
            .map(|p| p.utilization)
            .unwrap_or(0.0);
        s.push_str(&format!(
            "  {kdim:<4} {}  {}       {}     ({:.1} %)\n",
            cell(KernelKind::Fp32),
            cell(KernelKind::Fp8ToFp32),
            cell(KernelKind::Mx(fmt)),
            util * 100.0
        ));
    }
    s.push_str("\n(b) Energy efficiency [GFLOPS/W]\n");
    s.push_str("  K      FP32   FP8-to-FP32   MX-HW\n");
    for &kdim in &FIG4_K_SWEEP {
        let cell = |kind| {
            points
                .iter()
                .find(|p| p.k == kdim && p.kind == kind)
                .map(|p| format!("{:6.1}", p.gflops_per_w))
                .unwrap_or_else(|| "     —".into())
        };
        s.push_str(&format!(
            "  {kdim:<4} {}  {}       {}\n",
            cell(KernelKind::Fp32),
            cell(KernelKind::Fp8ToFp32),
            cell(KernelKind::Mx(fmt))
        ));
    }
    let h = headline(points);
    // A baseline can be absent from the sweep (FP32 never fits at
    // K=256; the FP8-software kernel only exists for the FP8 formats):
    // its ratio range then still holds the (f64::MAX, 0.0) init and
    // must render as a dash, not the sentinel.
    let range = |r: (f64, f64), prec: usize| {
        if r.0 == f64::MAX {
            "      —      ".to_string()
        } else {
            format!("{:.p$}x – {:.p$}x", r.0, r.1, p = prec)
        }
    };
    s.push_str(&format!(
        "\n§IV-C headline (measured vs paper):\n\
           peak throughput    {:6.1} GFLOPS      (paper 102)\n\
           peak efficiency    {:6.1} GFLOPS/W    (paper 356)\n\
           peak utilization   {:6.1} %           (paper 79.7)\n\
           speedup vs FP32    {}      (paper 3.1x – 3.4x)\n\
           speedup vs FP8-SW  {}      (paper 20.9x – 25.0x)\n\
           energy  vs FP32    {}      (paper 3.0x – 3.2x)\n\
           energy  vs FP8-SW  {}      (paper 10.4x – 12.5x)\n",
        h.peak_gflops,
        h.peak_gflops_per_w,
        h.peak_utilization * 100.0,
        range(h.speedup_vs_fp32, 2),
        range(h.speedup_vs_sw, 1),
        range(h.eff_vs_fp32, 2),
        range(h.eff_vs_sw, 1),
    ));
    s
}

/// Render Fig. 3 (core-complex area breakdown).
pub fn render_fig3() -> String {
    let m = AreaModel::derive();
    let mut s = String::new();
    s.push_str(&format!(
        "Fig. 3 — core-complex area breakdown (model derived from the paper's anchors)\n\
         cluster: {:.2} MGE extended / {:.2} MGE baseline (+{:.1} %), shared logic {:.2} MGE\n\
         core complex: {:.1} kGE; MXDOTP unit: {:.1} kGE ({:.1} % of core, {:.1} % of FPU)\n\n",
        m.cluster_mge,
        m.baseline_cluster_mge,
        (m.cluster_mge / m.baseline_cluster_mge - 1.0) * 100.0,
        m.shared_mge,
        m.core_complex_kge,
        m.mxdotp_kge,
        m.mxdotp_kge / m.core_complex_kge * 100.0,
        m.mxdotp_share_of_fpu() * 100.0,
    ));
    s.push_str("  component              kGE     share\n");
    for c in m.core_breakdown() {
        let bar = "#".repeat((c.share * 60.0).round() as usize);
        s.push_str(&format!("  {:<22} {:6.1}  {:5.1} %  {bar}\n", c.name, c.kge, c.share * 100.0));
    }
    s.push_str(&format!(
        "\n  alternative 4th RF read port would cost {:.1} kGE (+12 % of the FP RF) — avoided by SSR streaming\n",
        m.rf_4th_port_kge()
    ));
    s
}

/// Render Table III (units + clusters; our rows regenerated, third-
/// party rows cited).
pub fn render_table3(cluster_point: Option<&Fig4Point>) -> String {
    let area = AreaModel::derive();
    let em = EnergyModel;
    let (unit_gflops, unit_eff) = em.unit_peak();
    let mut s = String::new();
    s.push_str(
        "Table III — FP8 dot-product units (top) and compute clusters (bottom)\n\
         rows marked * are cited from the paper (third-party RTL); ours are regenerated\n\n",
    );
    s.push_str("  design                  tech  V     GHz    area[mm2]  scales    acc   GFLOPS  GFLOPS/W\n");
    let rows = table3_rows();
    for r in rows.iter().take(3) {
        s.push_str(&format!(
            "  {:<22}* {:>4}  {:<5} {:<6} {:<10.2e} {:<9} {:<5} {:>6.1}  {}\n",
            r.design,
            r.tech_nm,
            r.voltage.map(|v| v.to_string()).unwrap_or("—".into()),
            r.freq_ghz.map(|f| f.to_string()).unwrap_or("—".into()),
            r.area_mm2,
            r.scale_support,
            r.acc_format,
            r.gflops,
            r.gflops_per_w.map(|e| format!("{e:.0}")).unwrap_or("—".into()),
        ));
    }
    s.push_str(&format!(
        "  {:<22}  {:>4}  {:<5} {:<6} {:<10.2e} {:<9} {:<5} {:>6.1}  {:.0}   (paper: 17.4 / 2035)\n",
        "This work (unit)",
        12,
        k::VDD,
        k::UNIT_FREQ_GHZ,
        area.unit_mm2(),
        "2 x 8b",
        "FP32",
        unit_gflops,
        unit_eff,
    ));
    let mini = &rows[3];
    s.push_str(&format!(
        "  {:<22}* {:>4}  {:<5} {:<6} {:<10.2}   {:<9} {:<5} {:>6.1}  {}\n",
        mini.design,
        mini.tech_nm,
        mini.voltage.unwrap(),
        mini.freq_ghz.unwrap(),
        mini.area_mm2,
        mini.scale_support,
        mini.acc_format,
        mini.gflops,
        mini.gflops_per_w.map(|e| format!("{e:.0}")).unwrap(),
    ));
    if let Some(p) = cluster_point {
        s.push_str(&format!(
            "  {:<22}  {:>4}  {:<5} {:<6} {:<10.2}   {:<9} {:<5} {:>6.1}  {:.0}   (paper: 102 / 356)\n",
            "This work (cluster)",
            12,
            k::VDD,
            k::FREQ_GHZ,
            area.kge_to_mm2(area.cluster_mge * 1000.0),
            "2 x 8b",
            "FP32",
            p.gflops,
            p.gflops_per_w,
        ));
    }
    s.push_str(&format!(
        "\n  idle-power overhead of MXDOTP: +{:.1} % (paper: +1.9 %)\n",
        k::IDLE_OVERHEAD * 100.0
    ));
    s
}

/// The cluster-level MXFP8 point for Table III (K=256 run).
pub fn table3_cluster_point(seed: u64) -> Fig4Point {
    fig4_sweep(ElemFormat::E4M3, 8, seed)
        .into_iter()
        .filter(|p| matches!(p.kind, KernelKind::Mx(_)) && p.k == 256)
        .next_back()
        .expect("sweep must contain the K=256 MXFP8 point")
}

/// One row of the format sweep: the hardware kernel run on a Fig. 4
/// shape for one element format.
#[derive(Clone, Debug)]
pub struct FormatPoint {
    /// Element format of the run.
    pub fmt: ElemFormat,
    /// Inner dimension.
    pub k: usize,
    /// Achieved throughput (GFLOPS).
    pub gflops: f64,
    /// Energy efficiency (GFLOPS/W).
    pub gflops_per_w: f64,
    /// Fraction of the format's ideal throughput.
    pub utilization: f64,
    /// Simulated cycles.
    pub cycles: u64,
    /// `mxdotp` instructions executed.
    pub mxdotp: u64,
    /// Relative L2 error vs the f64 matmul of the same inputs (the
    /// precision side of the format trade-off).
    pub rel_err: f64,
}

/// Run the format-generic hardware kernel on the Fig. 4 shapes for
/// every OCP element format (the format-sweep table alongside
/// fig3/fig4/table3). Inputs are identical across formats, so
/// throughput and accuracy columns are directly comparable.
pub fn format_sweep(num_cores: usize, seed: u64, ks: &[usize]) -> Vec<FormatPoint> {
    let em = EnergyModel;
    let mut points = Vec::new();
    for &kdim in ks {
        let base = MmProblem::fig4(kdim, ElemFormat::E4M3);
        let mut rng = XorShift::new(seed ^ kdim as u64);
        let a = rng.normal_vec(base.m * base.k, 1.0);
        let b = rng.normal_vec(base.k * base.n, 1.0);
        let exact = crate::kernels::reference::matmul_f64(&base, &a, &b);
        for fmt in ElemFormat::ALL {
            let p = MmProblem { fmt, ..base };
            let run = run_mm(KernelKind::Mx(fmt), p, &a, &b, num_cores);
            let num: f64 =
                run.c.iter().zip(&exact).map(|(&g, &w)| (g as f64 - w).powi(2)).sum();
            let den: f64 = exact.iter().map(|&w| w * w).sum();
            points.push(FormatPoint {
                fmt,
                k: kdim,
                gflops: run.gflops(),
                gflops_per_w: em.gflops_per_w(&run.perf, p.flops(), run.freq_ghz, true),
                utilization: run.utilization(),
                cycles: run.perf.cycles,
                mxdotp: run.perf.mxdotp_total(),
                rel_err: (num / den).sqrt(),
            });
        }
    }
    points
}

/// Render the format sweep as text.
pub fn render_format_sweep(points: &[FormatPoint], num_cores: usize) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Format sweep — the format-generic MX datapath on the Fig. 4 shapes \
         (M=N=64, {num_cores} cores @ 1 GHz)\n\
         (lanes/issue: 8 for FP8/FP6/INT8, 16 for FP4 -> 32 ideal FLOPs/cycle/core)\n\n"
    ));
    s.push_str("  K    fmt     GFLOPS   util     GFLOPS/W   rel.err    mxdotp\n");
    for p in points {
        s.push_str(&format!(
            "  {:<4} {:<6} {:>7.1}  {:>5.1} %  {:>8.1}   {:<9.5}{:>9}\n",
            p.k,
            p.fmt.name(),
            p.gflops,
            p.utilization * 100.0,
            p.gflops_per_w,
            p.rel_err,
            p.mxdotp
        ));
    }
    // the headline ratio the FP4 path exists for
    if let (Some(f8), Some(f4)) = (
        points.iter().filter(|p| p.fmt == ElemFormat::E4M3).max_by_key(|p| p.k),
        points.iter().filter(|p| p.fmt == ElemFormat::E2M1).max_by_key(|p| p.k),
    ) {
        s.push_str(&format!(
            "\n  MXFP4 vs MXFP8 at K={}: {:.2}x throughput ({:.1} vs {:.1} GFLOPS) at \
             {:.1} %/{:.1} % utilization\n",
            f8.k,
            f4.gflops / f8.gflops,
            f4.gflops,
            f8.gflops,
            f4.utilization * 100.0,
            f8.utilization * 100.0,
        ));
    }
    s
}

/// The default strong-scaling sweep (the scale-out scaling table).
pub const SCALING_CLUSTERS: [usize; 4] = [1, 2, 4, 8];

/// One row of the scale-out scaling table: the DeiT-Tiny MX matmuls
/// executed on an N-cluster fabric.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// Fabric size of this row.
    pub clusters: usize,
    /// Fabric wall-clock summed over the workload's layers (max over
    /// clusters within each layer).
    pub wall_cycles: u64,
    /// Total busy cycles across clusters and layers.
    pub total_cycles: u64,
    /// Total fabric energy (µJ).
    pub energy_uj: f64,
    /// Useful FLOPs of the workload.
    pub flops: u64,
    /// Fabric throughput (GFLOPS).
    pub gflops: f64,
    /// Fabric energy efficiency (GFLOPS/W).
    pub gflops_per_w: f64,
    /// Strong-scaling speedup vs the sweep's first point.
    pub speedup: f64,
    /// Parallel efficiency: speedup normalized by the cluster ratio.
    pub efficiency: f64,
}

/// Run the DeiT-Tiny MX matmul workload (`cfg.mx_matmuls()`, executed
/// layer by layer) on each fabric size in `clusters_list`, through the
/// cycle-accurate scale-out engine. Inputs are the same for every
/// fabric size, so results are bit-comparable across the sweep.
///
/// With warm plans (`cold_plans = false`, the default path) the sweep
/// reuses compiled programs, quantized B tiles and memoized passes
/// across fabric sizes — under M-split every fabric size executes the
/// *same* per-cluster passes, just distributed differently, so the
/// 2/4/8-cluster points cost almost no additional host time. Simulated
/// cycles/energy are bit-identical either way.
pub fn scaleout_scaling(
    cfg: &DeitConfig,
    clusters_list: &[usize],
    seed: u64,
    cold_plans: bool,
) -> Vec<ScalingPoint> {
    assert!(!clusters_list.is_empty());
    let layers = cfg.mx_matmuls();
    let mut points: Vec<ScalingPoint> = Vec::with_capacity(clusters_list.len());
    for &clusters in clusters_list {
        let scfg = ScaleoutConfig {
            cold_plans,
            vector_len: cfg.vector_len.max(1) as usize,
            ..ScaleoutConfig::with_clusters(clusters)
        };
        let mut wall = 0u64;
        let mut total = 0u64;
        let mut energy = 0.0f64;
        let mut flops = 0u64;
        for (li, p) in layers.iter().enumerate() {
            let mut rng = XorShift::new(seed ^ ((li as u64 + 1) << 32));
            let a = rng.normal_vec(p.m * p.k, 0.5);
            let b = rng.normal_vec(p.k * p.n, 0.02);
            let run = sharded_mm(&scfg, *p, &a, &b);
            wall += run.wall_cycles;
            total += run.total_cycles;
            energy += run.total_energy_uj;
            flops += p.flops();
        }
        let time_us = wall as f64 / (scfg.freq_ghz * 1e3);
        let gflops = flops as f64 / wall as f64 * scfg.freq_ghz;
        let avg_power_w = if time_us > 0.0 { energy / time_us } else { 0.0 };
        let (speedup, efficiency) = match points.first() {
            None => (1.0, 1.0),
            Some(base) => {
                let s = base.wall_cycles as f64 / wall as f64;
                (s, s * base.clusters as f64 / clusters as f64)
            }
        };
        points.push(ScalingPoint {
            clusters,
            wall_cycles: wall,
            total_cycles: total,
            energy_uj: energy,
            flops,
            gflops,
            gflops_per_w: if avg_power_w > 0.0 { gflops / avg_power_w } else { 0.0 },
            speedup,
            efficiency,
        });
    }
    points
}

/// Render the scale-out scaling table.
pub fn render_scaling(points: &[ScalingPoint], cfg: &DeitConfig) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Scale-out — DeiT-Tiny MX matmuls (seq {}, dim {}, {fmt}) sharded across \
         N simulated Snitch clusters\n(wall-clock = max over clusters per layer; \
         energy = fabric total; M-split, bit-identical results)\n\n",
        cfg.seq,
        cfg.dim,
        fmt = cfg.fmt
    ));
    s.push_str("  clusters   wall cycles   speedup   par.eff   GFLOPS   GFLOPS/W   energy[µJ]\n");
    for p in points {
        s.push_str(&format!(
            "  {:<8}  {:>12}   {:>6.2}x   {:>6.1} %  {:>7.1}   {:>8.1}   {:>10.1}\n",
            p.clusters,
            p.wall_cycles,
            p.speedup,
            p.efficiency * 100.0,
            p.gflops,
            p.gflops_per_w,
            p.energy_uj
        ));
    }
    s
}

/// Offered-load multipliers of the serving sweep, as fractions of the
/// continuous engine's estimated capacity — from comfortable (0.25×)
/// to deep overload (4×), where the schedulers separate.
pub const SERVING_LOAD_MULTS: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];

/// One row of the serving table: one scheduler at one offered load.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    /// Offered load as a multiple of estimated capacity.
    pub load_mult: f64,
    /// Offered load in requests per kilotick.
    pub offered_per_ktick: f64,
    /// Scheduler that produced this row.
    pub sched: SchedulerKind,
    /// Requests offered / served / rejected (queue-full, SLO).
    pub offered: usize,
    /// Requests completed.
    pub served: usize,
    /// Rejections due to the queue cap.
    pub rejected_full: usize,
    /// Rejections due to SLO unattainability.
    pub rejected_slo: usize,
    /// Served requests that met the SLO.
    pub in_slo: usize,
    /// SLO-compliant completions per kilotick (the headline metric).
    pub goodput_per_ktick: f64,
    /// Raw completions per kilotick.
    pub throughput_per_ktick: f64,
    /// Latency percentiles in ticks (1 tick = 1 µs of fabric time).
    pub p50: u64,
    /// 95th percentile latency (ticks).
    pub p95: u64,
    /// 99th percentile latency (ticks).
    pub p99: u64,
    /// Mean requests per dispatched batch.
    pub mean_batch: f64,
    /// Fraction of fabric·ticks spent busy.
    pub fabric_util: f64,
    /// Weight reloads (format switches) paid.
    pub reloads: u64,
}

/// Run the serving comparison: for each load multiplier, generate one
/// Poisson trace at `mult ×` the continuous engine's estimated
/// capacity and run **both** schedulers over the *identical* trace,
/// measured against the same SLO (resolved once from the continuous
/// config, so the barrier baseline is judged by the same yardstick it
/// is compared against).
pub fn serving_sweep(
    cfg: &ServeConfig,
    mix: &[(ElemFormat, f64)],
    requests: usize,
    seed: u64,
    load_mults: &[f64],
) -> Vec<ServingPoint> {
    let cont = ServeConfig { scheduler: SchedulerKind::Continuous, ..*cfg };
    let capacity = serve::estimated_capacity_per_ktick(&cont, mix);
    let slo = serve::resolve_slo_ticks(&cont);
    let mut points = Vec::with_capacity(load_mults.len() * 2);
    for (li, &mult) in load_mults.iter().enumerate() {
        let rate = capacity * mult;
        let spec = ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick: rate,
            mix: mix.to_vec(),
            high_priority_frac: 0.0,
            requests,
            seed: seed.wrapping_add(li as u64 * 7919),
        };
        let trace = generate_trace(&spec);
        for sched in [SchedulerKind::Barrier, SchedulerKind::Continuous] {
            let run_cfg = ServeConfig { scheduler: sched, slo_ticks: slo, ..*cfg };
            let out = serve::simulate(&run_cfg, &trace);
            let p = out.percentiles();
            points.push(ServingPoint {
                load_mult: mult,
                offered_per_ktick: rate,
                sched,
                offered: out.offered(),
                served: out.served.len(),
                rejected_full: out.rejected_queue_full(),
                rejected_slo: out.rejected_slo(),
                in_slo: out.served_in_slo(),
                goodput_per_ktick: out.goodput_per_ktick(),
                throughput_per_ktick: out.throughput_per_ktick(),
                p50: p.p50,
                p95: p.p95,
                p99: p.p99,
                mean_batch: out.mean_batch_size(),
                fabric_util: out.fabric_utilization(),
                reloads: out.reloads,
            });
        }
    }
    points
}

/// Goodput ratio (continuous / barrier) at the highest offered load of
/// a sweep; `f64::INFINITY` when the barrier's goodput is zero there.
pub fn serving_headline_ratio(points: &[ServingPoint]) -> Option<f64> {
    let top = points
        .iter()
        .map(|p| p.load_mult)
        .fold(f64::NEG_INFINITY, f64::max);
    let at = |s: SchedulerKind| {
        points.iter().find(|p| p.load_mult == top && p.sched == s).map(|p| p.goodput_per_ktick)
    };
    let (c, b) = (at(SchedulerKind::Continuous)?, at(SchedulerKind::Barrier)?);
    Some(if b > 0.0 { c / b } else { f64::INFINITY })
}

/// Render the serving table (goodput vs offered load, both
/// schedulers) plus the §12 headline ratio.
pub fn render_serving(points: &[ServingPoint], cfg: &ServeConfig, mix: &[(ElemFormat, f64)]) -> String {
    let cont = ServeConfig { scheduler: SchedulerKind::Continuous, ..*cfg };
    let slo = serve::resolve_slo_ticks(&cont);
    let mix_s: Vec<String> =
        mix.iter().map(|(f, w)| format!("{}:{:.2}", f.name(), w)).collect();
    let mut s = String::new();
    s.push_str(&format!(
        "Serving — goodput vs offered load on a {}-cluster machine (mix {}, SLO {} ticks)\n\
         continuous: {} fabric(s) × {} cluster(s), per-format queues, SLO-aware admission, \
         in-flight splice\nbarrier: the seed FIFO batcher on one whole-machine fabric \
         (latency-blind admission)\nboth schedulers consume identical traces; \
         1 tick = 1 µs of fabric time\n\n",
        cfg.clusters,
        mix_s.join(","),
        slo,
        cont.fabric_count(),
        cont.clusters_per_fabric(),
    ));
    s.push_str(
        "  load   offered[/kt]  sched        served  rej full/slo   in-SLO  goodput[/kt]  \
         p50     p95     p99     batch  util\n",
    );
    for p in points {
        let load = format!("{:.2}x", p.load_mult);
        s.push_str(&format!(
            "  {:<5} {:>10.2}    {:<11} {:>6}  {:>5}/{:<5}   {:>6}  {:>10.2}    \
             {:>6}  {:>6}  {:>6}  {:>5.1}  {:>5.1} %\n",
            load,
            p.offered_per_ktick,
            p.sched.name(),
            p.served,
            p.rejected_full,
            p.rejected_slo,
            p.in_slo,
            p.goodput_per_ktick,
            p.p50,
            p.p95,
            p.p99,
            p.mean_batch,
            p.fabric_util * 100.0,
        ));
    }
    if let Some(ratio) = serving_headline_ratio(points) {
        let shown = if ratio.is_finite() {
            format!("{ratio:.2}x")
        } else {
            "∞ (barrier goodput 0)".to_string()
        };
        s.push_str(&format!(
            "\n  headline: continuous vs barrier goodput at the top load = {shown}   \
             (acceptance bar ≥ 1.5x)\n"
        ));
    }
    s
}

/// Machine counts of the fleet sweep (`reproduce fleet`).
pub const FLEET_MACHINES: [usize; 3] = [1, 2, 4];

/// Offered load of the fleet sweep as a fraction of the fleet's
/// no-reload capacity estimate: high enough that a router wasting
/// fabric ticks on avoidable weight reloads visibly loses goodput,
/// low enough that the affinity fleet still clears the trace.
pub const FLEET_LOAD_MULT: f64 = 0.9;

/// The canonical mixed-policy traffic classes of the fleet sweep:
/// four equal-weight precision policies keyed 1:1 to arrival formats,
/// so each request's policy is a deterministic function of its mix
/// class. Equal weights mean a 4-machine fleet admits a perfect
/// one-class-per-machine placement — exactly what the affinity router
/// should find and round-robin structurally cannot.
pub fn fleet_mix_classes() -> Vec<(ElemFormat, PrecisionPolicy, f64)> {
    vec![
        (ElemFormat::E4M3, PrecisionPolicy::preset("all-fp8").unwrap(), 0.25),
        (ElemFormat::E2M1, PrecisionPolicy::preset("all-fp4").unwrap(), 0.25),
        (ElemFormat::E5M2, PrecisionPolicy::preset("fp4-ffn").unwrap(), 0.25),
        (ElemFormat::Int8, PrecisionPolicy::preset("all-int8").unwrap(), 0.25),
    ]
}

/// The canonical fleet machine of the sweep and the fleet bench: all
/// clusters fused into ONE whole-machine fabric, so precision-policy
/// residency is machine-global — exactly the placement decision the
/// routers differ on (a per-cluster-fabric machine can quietly
/// specialize fabrics per policy and mask the router's mistake) — and
/// batch 4, so a routing mistake's weight reload is amortized over
/// few requests.
pub fn fleet_machine(model: DeitConfig) -> ServeConfig {
    ServeConfig { model, clusters: 8, fabrics: 1, max_batch: 4, ..ServeConfig::default() }
}

/// Generate the fleet sweep's mixed-policy trace for one machine
/// count: Poisson arrivals at [`FLEET_LOAD_MULT`] × the N-machine
/// no-reload capacity, each request carrying its class's policy.
pub fn fleet_trace(
    cfg: &ServeConfig,
    machines: usize,
    requests: usize,
    seed: u64,
) -> Vec<Arrival> {
    let classes = fleet_mix_classes();
    let pol_mix: Vec<(PrecisionPolicy, f64)> =
        classes.iter().map(|&(_, p, w)| (p, w)).collect();
    let per_machine = serve::estimated_capacity_for_policies(cfg, &pol_mix);
    let spec = ArrivalSpec {
        kind: ArrivalKind::Poisson,
        rate_per_ktick: FLEET_LOAD_MULT * machines as f64 * per_machine,
        mix: classes.iter().map(|&(f, _, w)| (f, w)).collect(),
        high_priority_frac: 0.0,
        requests,
        seed,
    };
    let mut trace = generate_trace(&spec);
    assign_policy_classes(&mut trace, &classes, seed ^ 0x5a5a);
    trace
}

/// One row of the fleet table: one router at one machine count.
#[derive(Clone, Debug)]
pub struct FleetPoint {
    /// Machines in the fleet.
    pub machines: usize,
    /// Router that produced this row.
    pub router: RouterKind,
    /// Requests offered to the fleet.
    pub offered: usize,
    /// Requests completed across all machines.
    pub served: usize,
    /// Served requests that met the SLO.
    pub in_slo: usize,
    /// SLO-compliant completions per kilotick (the headline metric).
    pub goodput_per_ktick: f64,
    /// Merged-population latency percentiles in ticks.
    pub p50: u64,
    /// 95th percentile latency (ticks).
    pub p95: u64,
    /// 99th percentile latency (ticks).
    pub p99: u64,
    /// Weight reloads paid across all machines.
    pub reloads: u64,
    /// Fabric ticks burned on those reloads.
    pub reload_ticks: u64,
    /// Fleet-wide fabric utilization.
    pub utilization: f64,
}

/// Run the fleet comparison: for each machine count, generate one
/// mixed-policy trace at the matching offered load and run **both**
/// routers over the *identical* trace (DESIGN.md §17). The 1-machine
/// rows are the degenerate-fleet sanity anchor — with one machine the
/// routers cannot differ.
pub fn fleet_sweep(
    cfg: &ServeConfig,
    requests: usize,
    seed: u64,
    machine_counts: &[usize],
) -> Vec<FleetPoint> {
    let costs = serve::CostModel::build(cfg);
    let mut points = Vec::with_capacity(machine_counts.len() * 2);
    for (mi, &n) in machine_counts.iter().enumerate() {
        let trace = fleet_trace(cfg, n, requests, seed.wrapping_add(mi as u64 * 7919));
        for router in [RouterKind::RoundRobin, RouterKind::Affinity] {
            let fcfg = FleetConfig::new(*cfg, n, router);
            let out = simulate_fleet(&fcfg, &trace, &[]);
            let p = out.percentiles();
            points.push(FleetPoint {
                machines: n,
                router,
                offered: out.offered(),
                served: out.served(),
                in_slo: out.served_in_slo(),
                goodput_per_ktick: out.goodput_per_ktick(),
                p50: p.p50,
                p95: p.p95,
                p99: p.p99,
                reloads: out.reloads(),
                reload_ticks: out.reload_ticks(&costs),
                utilization: out.utilization(),
            });
        }
    }
    points
}

/// Goodput ratio (affinity / round-robin) at the largest machine count
/// of a sweep; `f64::INFINITY` when round-robin's goodput is zero.
pub fn fleet_headline_ratio(points: &[FleetPoint]) -> Option<f64> {
    let top = points.iter().map(|p| p.machines).max()?;
    let at = |r: RouterKind| {
        points
            .iter()
            .find(|p| p.machines == top && p.router == r)
            .map(|p| p.goodput_per_ktick)
    };
    let (aff, rr) = (at(RouterKind::Affinity)?, at(RouterKind::RoundRobin)?);
    Some(if rr > 0.0 { aff / rr } else { f64::INFINITY })
}

/// Render the fleet table (goodput vs machine count, both routers)
/// plus the §17 headline ratio.
pub fn render_fleet(points: &[FleetPoint], cfg: &ServeConfig) -> String {
    let slo = serve::resolve_slo_ticks(cfg);
    let classes = fleet_mix_classes();
    let mix_s: Vec<String> = classes
        .iter()
        .map(|(f, p, w)| format!("{}→{p}:{w:.1}", f.name()))
        .collect();
    let mut s = String::new();
    s.push_str(&format!(
        "Fleet — goodput vs machine count, affinity vs round-robin routing \
         (DESIGN.md §17)\neach machine: {} cluster(s) as {} fabric(s); offered load \
         {:.2}× the fleet's no-reload capacity; SLO {} ticks\nmixed-policy traffic \
         {}; both routers consume identical traces\n\n",
        cfg.clusters,
        cfg.fabric_count(),
        FLEET_LOAD_MULT,
        slo,
        mix_s.join(", "),
    ));
    s.push_str(
        "  machines  router     served/offered   in-SLO  goodput[/kt]  p50     p95     \
         p99     reloads  reload-ticks  util\n",
    );
    for p in points {
        s.push_str(&format!(
            "  {:>8}  {:<9} {:>7}/{:<8} {:>6}  {:>10.2}  {:>6}  {:>6}  {:>6}  {:>7}  \
             {:>12}  {:>5.1} %\n",
            p.machines,
            p.router.name(),
            p.served,
            p.offered,
            p.in_slo,
            p.goodput_per_ktick,
            p.p50,
            p.p95,
            p.p99,
            p.reloads,
            p.reload_ticks,
            p.utilization * 100.0,
        ));
    }
    if let Some(ratio) = fleet_headline_ratio(points) {
        let shown = if ratio.is_finite() {
            format!("{ratio:.2}x")
        } else {
            "∞ (round-robin goodput 0)".to_string()
        };
        s.push_str(&format!(
            "\n  headline: affinity vs round-robin goodput at the largest fleet = {shown}   \
             (acceptance bar ≥ 1.15x)\n"
        ));
    }
    s
}

/// The precision-policy presets of the Pareto sweep, most accurate
/// first: MXINT8 / MXFP8 / mixed FP8+FP4 / MXFP4 over the four linear
/// projections (attention internals FP32, the paper's recipe).
pub const PARETO_PRESETS: [&str; 4] = ["all-int8", "all-fp8", "fp4-ffn", "all-fp4"];

/// Probe inputs per policy for the accuracy column (seeds
/// `seed+1..=seed+N` through `workload::generate_input`).
pub const PARETO_PROBE_INPUTS: usize = 2;

/// One point of the accuracy/throughput Pareto sweep: a precision
/// policy with its cycle-accurate fabric throughput and its
/// end-to-end accuracy against the FP32 reference.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// Preset (or custom-policy) name.
    pub name: String,
    /// The policy swept.
    pub policy: PrecisionPolicy,
    /// Cycle-accurate hardware walk of the policy's MX layers.
    pub hw: PolicyHwRun,
    /// Mean relative L2 error of the encoder-block output vs the
    /// all-FP32 reference forward pass, over the probe inputs.
    pub rel_err: f64,
}

impl ParetoPoint {
    /// Fabric throughput over the policy's MX layers (GFLOPS, 1 GHz).
    pub fn gflops(&self) -> f64 {
        self.hw.gflops()
    }
}

/// The named presets of [`PARETO_PRESETS`] as `(name, policy)` pairs.
pub fn pareto_presets() -> Vec<(String, PrecisionPolicy)> {
    PARETO_PRESETS
        .iter()
        .map(|&n| (n.to_string(), PrecisionPolicy::preset(n).expect("known preset")))
        .collect()
}

/// Run the accuracy/throughput Pareto sweep (DESIGN.md §13): for each
/// policy, (a) walk the model graph's MX layers through the
/// cycle-accurate scale-out engine ([`policy_hw_run`], warm plans
/// shared across policies for the layers they agree on), and (b) run
/// the full encoder block through the host [`GraphExecutor`] on
/// [`PARETO_PROBE_INPUTS`] probe inputs, measuring the mean relative
/// L2 error against the all-FP32 reference executor over the same
/// inputs and parameters.
///
/// Results are a pure function of the arguments; `cold_plans` changes
/// host wall-clock only.
pub fn pareto_sweep(
    cfg: &DeitConfig,
    policies: &[(String, PrecisionPolicy)],
    clusters: usize,
    num_cores: usize,
    seed: u64,
    cold_plans: bool,
) -> Vec<ParetoPoint> {
    assert!(!policies.is_empty());
    let graph = ModelGraph::deit_block(cfg);
    let params = generate_params(cfg, 42);
    let inputs: Vec<Vec<f32>> =
        (0..PARETO_PROBE_INPUTS).map(|i| generate_input(cfg, seed + 1 + i as u64)).collect();
    let reference =
        GraphExecutor::new(*cfg, PrecisionPolicy::fp32_reference(), params.clone())
            .expect("the FP32 reference policy quantizes nothing");
    let refs: Vec<Vec<f32>> =
        inputs.iter().map(|x| reference.forward_ref(x).expect("probe shape")).collect();
    policies
        .iter()
        .map(|(name, policy)| {
            let exec = GraphExecutor::new(*cfg, *policy, params.clone())
                .unwrap_or_else(|e| panic!("policy {name} invalid for these shapes: {e}"));
            let mut err_sum = 0.0f64;
            for (x, r) in inputs.iter().zip(&refs) {
                let y = exec.forward_ref(x).expect("probe shape");
                let num: f64 =
                    y.iter().zip(r).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
                let den: f64 = r.iter().map(|&v| (v as f64).powi(2)).sum();
                err_sum += (num / den).sqrt();
            }
            let hw = policy_hw_run(
                &graph,
                policy,
                clusters,
                num_cores,
                seed,
                cold_plans,
                cfg.vector_len,
            );
            ParetoPoint {
                name: name.clone(),
                policy: *policy,
                hw,
                rel_err: err_sum / inputs.len() as f64,
            }
        })
        .collect()
}

/// The sweep's headline pair: fp4-ffn vs all-fp8 (throughput ratio,
/// error ratio). `None` unless both presets are in the sweep.
pub fn pareto_headline(points: &[ParetoPoint]) -> Option<(f64, f64)> {
    let get = |n: &str| points.iter().find(|p| p.name == n);
    let (fp8, ffn4) = (get("all-fp8")?, get("fp4-ffn")?);
    if fp8.gflops() <= 0.0 || fp8.rel_err <= 0.0 {
        return None;
    }
    Some((ffn4.gflops() / fp8.gflops(), ffn4.rel_err / fp8.rel_err))
}

/// Render the Pareto sweep as text: one row per policy (throughput,
/// wall, energy, accuracy, CSR switches, ratios vs `all-fp8`) plus the
/// fp4-ffn headline against its ≥1.3× throughput bar.
pub fn render_pareto(points: &[ParetoPoint], cfg: &DeitConfig, clusters: usize) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Pareto — per-layer mixed-precision presets on the DeiT-Tiny graph \
         (seq {}, dim {}, {clusters} cluster(s), block {})\n\
         accuracy: mean relative L2 error of the block output vs the FP32 reference \
         ({} probe inputs)\nthroughput: cycle-accurate fabric wall-clock over each \
         policy's MX-quantized GEMMs (attention\ninternals stay FP32 host math in \
         every preset — the paper's recipe)\n\n",
        cfg.seq, cfg.dim, cfg.block_size, PARETO_PROBE_INPUTS,
    ));
    s.push_str(
        "  policy     GFLOPS   wall cycles   energy[µJ]   rel.err    csr-sw   \
         vs all-fp8 thr/err\n",
    );
    let fp8 = points.iter().find(|p| p.name == "all-fp8");
    for p in points {
        let vs = match fp8 {
            Some(b) if b.gflops() > 0.0 && b.rel_err > 0.0 => format!(
                "{:>5.2}x / {:>5.2}x",
                p.gflops() / b.gflops(),
                p.rel_err / b.rel_err
            ),
            _ => "      —      ".into(),
        };
        s.push_str(&format!(
            "  {:<9} {:>7.1}  {:>12}  {:>10.1}   {:<9.5}  {:>4}    {vs}\n",
            p.name,
            p.gflops(),
            p.hw.wall_cycles,
            p.hw.total_energy_uj,
            p.rel_err,
            p.hw.csr_switches,
        ));
    }
    if let Some((thr, err)) = pareto_headline(points) {
        s.push_str(&format!(
            "\n  headline: fp4-ffn reaches {thr:.2}x the all-fp8 throughput \
             (bar ≥ 1.30x) at {err:.2}x its error\n  (direct-cast MXFP4 in the FFN \
             costs ~4x the MXFP8 error on these moment-matched shapes —\n  the \
             measured frontier, consistent with the MX literature's direct-cast \
             results)\n"
        ));
    }
    s
}

/// One point of the training sweep (DESIGN.md §18): a (policy,
/// rounding) pair with its loss curve and its cycle-accurate
/// cycles/step.
#[derive(Clone, Debug)]
pub struct TrainingPoint {
    /// Point name (`fp32`, `<policy>-rne`, `<policy>-stochastic`).
    pub name: String,
    /// Quantizer rounding mode of the training numerics.
    pub rounding: Rounding,
    /// RNE-evaluated loss per step (`steps + 1` entries, last =
    /// final).
    pub losses: Vec<f64>,
    /// Cycle-accurate fabric cost of one training step (forward +
    /// backward MX GEMMs; zero-cycle for the FP32 reference).
    pub hw: TrainingHwRun,
    /// Probe-calibrated analytic prediction of `hw.wall_cycles`
    /// ([`analytic_training_cycles`]).
    pub analytic_cycles: u64,
}

impl TrainingPoint {
    /// Loss after the last SGD step.
    pub fn final_loss(&self) -> f64 {
        *self.losses.last().expect("a run records at least the initial loss")
    }

    /// Relative error of the analytic cycles/step prediction against
    /// the measured fabric walk (0 for the FP32 point, which issues no
    /// MX GEMMs).
    pub fn analytic_rel_err(&self) -> f64 {
        if self.hw.wall_cycles == 0 {
            return 0.0;
        }
        (self.hw.wall_cycles as f64 - self.analytic_cycles as f64).abs()
            / self.hw.wall_cycles as f64
    }
}

/// Run the training sweep: fine-tune the block under (a) the FP32
/// reference, (b) `policy` with RNE rounding, (c) `policy` with
/// seeded stochastic rounding — same `TrainConfig` otherwise — and
/// price one training step of the MX policy on the fabric (one
/// cycle-accurate walk serves both rounding modes: the engine is
/// RNE-only, DESIGN.md §18, and cycles are rounding-independent).
///
/// `policy` applies to forward *and* backward here (the sweep's
/// purpose is the rounding comparison, not mixed recipes — those are
/// exposed through [`Trainer`] directly). Results are a pure function
/// of the arguments.
pub fn training_sweep(
    cfg: &DeitConfig,
    policy_name: &str,
    policy: &PrecisionPolicy,
    tcfg: &TrainConfig,
    stochastic_seed: u64,
    clusters: usize,
    num_cores: usize,
) -> Vec<TrainingPoint> {
    let graph = ModelGraph::deit_block(cfg);
    let fp32 = PrecisionPolicy::fp32_reference();
    let zero_hw = TrainingHwRun {
        forward_wall_cycles: 0,
        backward_wall_cycles: 0,
        wall_cycles: 0,
        total_energy_uj: 0.0,
        flops: 0,
    };
    let hw = training_hw_run(
        &graph,
        policy,
        policy,
        clusters,
        num_cores,
        tcfg.seed,
        cfg.vector_len,
    );
    let analytic = analytic_training_cycles(&graph, policy, policy, num_cores, cfg.vector_len);
    let run_at = |pol: &PrecisionPolicy, rounding: Rounding| -> Vec<f64> {
        Trainer::new(*cfg, *pol, *pol, TrainConfig { rounding, ..*tcfg })
            .unwrap_or_else(|e| panic!("training policy invalid for these shapes: {e}"))
            .run()
            .losses
    };
    vec![
        TrainingPoint {
            name: "fp32".into(),
            rounding: Rounding::Rne,
            losses: run_at(&fp32, Rounding::Rne),
            hw: zero_hw,
            analytic_cycles: 0,
        },
        TrainingPoint {
            name: format!("{policy_name}-rne"),
            rounding: Rounding::Rne,
            losses: run_at(policy, Rounding::Rne),
            hw: hw.clone(),
            analytic_cycles: analytic,
        },
        TrainingPoint {
            name: format!("{policy_name}-stochastic"),
            rounding: Rounding::Stochastic(stochastic_seed),
            losses: run_at(policy, Rounding::Stochastic(stochastic_seed)),
            hw,
            analytic_cycles: analytic,
        },
    ]
}

/// Loss-curve fidelity of the sweep: `(rne_gap, stochastic_gap)`,
/// each the absolute final-loss gap of a quantized point against the
/// FP32 reference point. `None` unless the sweep has the standard
/// three points.
pub fn training_fidelity(points: &[TrainingPoint]) -> Option<(f64, f64)> {
    let fp32 = points.iter().find(|p| p.name == "fp32")?;
    let rne = points.iter().find(|p| p.name.ends_with("-rne"))?;
    let stoch = points.iter().find(|p| p.name.ends_with("-stochastic"))?;
    Some((
        (rne.final_loss() - fp32.final_loss()).abs(),
        (stoch.final_loss() - fp32.final_loss()).abs(),
    ))
}

/// The sweep's headline gate metric: the stochastic final-loss gap
/// over the RNE gap, ε-regularized so two near-zero gaps read as
/// ratio ≈ 1 instead of noise (`ε = 5% of the FP32 final loss`).
/// `BENCH_training.json` gates this ≤ 2.0.
pub fn training_gap_ratio(points: &[TrainingPoint]) -> Option<f64> {
    let (rne_gap, stoch_gap) = training_fidelity(points)?;
    let fp32 = points.iter().find(|p| p.name == "fp32")?;
    let eps = 0.05 * fp32.final_loss() + 1e-9;
    Some((stoch_gap + eps) / (rne_gap + eps))
}

/// Render the training sweep as text: one row per point (loss curve
/// endpoints, gap vs FP32, cycles/step vs the analytic model) plus
/// the stochastic-vs-RNE fidelity headline against its ≤ 2.0 bar.
pub fn render_training(points: &[TrainingPoint], cfg: &DeitConfig, tcfg: &TrainConfig) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Training — low-precision fine-tuning of the DeiT block (seq {}, dim {}, \
         {} steps, lr {}, batch {})\nloss: teacher-student MSE, evaluated with an RNE \
         forward pass every step; backward-pass dX/dW GEMMs\nrun at the policy's MX \
         precision with the point's rounding mode (DESIGN.md \u{a7}18)\n\n",
        cfg.seq, cfg.dim, tcfg.steps, tcfg.lr, tcfg.batch,
    ));
    s.push_str(
        "  point                 initial loss   final loss   gap vs fp32   \
         cycles/step   analytic (rel err)\n",
    );
    let fp32_final = points.iter().find(|p| p.name == "fp32").map(|p| p.final_loss());
    for p in points {
        let gap = match fp32_final {
            Some(f) if p.name != "fp32" => format!("{:.3e}", (p.final_loss() - f).abs()),
            _ => "—".into(),
        };
        let analytic = if p.hw.wall_cycles == 0 {
            "—".into()
        } else {
            format!("{} ({:.1}%)", p.analytic_cycles, p.analytic_rel_err() * 100.0)
        };
        s.push_str(&format!(
            "  {:<21} {:>12.4e}  {:>11.4e}  {:>12}  {:>12}   {analytic}\n",
            p.name,
            p.losses.first().copied().unwrap_or(f64::NAN),
            p.final_loss(),
            gap,
            p.hw.wall_cycles,
        ));
    }
    if let (Some(ratio), Some((rne_gap, stoch_gap))) =
        (training_gap_ratio(points), training_fidelity(points))
    {
        s.push_str(&format!(
            "\n  headline: stochastic/RNE final-loss-gap ratio = {ratio:.2} \
             (bar \u{2264} 2.00; gaps {stoch_gap:.3e} vs {rne_gap:.3e})\n  \
             unbiased stochastic rounding tracks RNE's converged loss while \
             de-biasing gradient\n  accumulation — the ExSdotp + stochastic \
             recipe of the MX training literature\n"
        ));
    }
    s
}

/// Summarize an MmRun for CLI output.
pub fn render_run(run: &MmRun) -> String {
    let em = EnergyModel;
    let with_mx = matches!(run.kind, KernelKind::Mx(_) | KernelKind::VMx(..));
    let power = em.power(&run.perf, run.freq_ghz, with_mx);
    format!(
        "{} {}x{}x{} ({} cores): {} cycles, {:.1} GFLOPS ({:.1} % of ideal), {:.1} mW, {:.1} GFLOPS/W",
        run.kind.name(),
        run.problem.m,
        run.problem.k,
        run.problem.n,
        run.num_cores,
        run.perf.cycles,
        run.gflops(),
        run.utilization() * 100.0,
        power.total_mw,
        em.gflops_per_w(&run.perf, run.problem.flops(), run.freq_ghz, with_mx)
    )
}

/// Note printed after writing a `--trace-out` Perfetto trace file.
pub fn render_trace_note(path: &str) -> String {
    format!("wrote Perfetto trace to {path} — open it at https://ui.perfetto.dev")
}

/// Note printed after writing a `--obs-out` metrics-registry file.
pub fn render_obs_note(path: &str) -> String {
    format!("wrote observability metrics to {path}")
}

/// Detailed run report: summary line + cycle-accounting breakdown.
pub fn render_run_detailed(run: &MmRun) -> String {
    let bd = crate::snitch::trace::CycleBreakdown::from_perf(&run.perf, |c| match run.kind {
        KernelKind::Mx(_) => c.mxdotp,
        KernelKind::VMx(..) => c.vmxdotp,
        KernelKind::Fp32 => c.vfmac,
        KernelKind::Fp8ToFp32 => c.fma_s,
    });
    format!("{}\n{}", render_run(run), bd.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_contains_published_numbers() {
        let s = render_fig3();
        assert!(s.contains("4.89 MGE"));
        assert!(s.contains("+5.1 %"));
        assert!(s.contains("MXDOTP unit"));
    }

    #[test]
    fn obs_notes_name_the_artifact_paths() {
        assert!(render_trace_note("out/t.json").contains("out/t.json"));
        assert!(render_trace_note("t.json").contains("ui.perfetto.dev"));
        assert!(render_obs_note("m.json").contains("m.json"));
    }

    #[test]
    fn table3_lists_all_rows() {
        let s = render_table3(None);
        for d in ["ExSdotp", "Desrentes", "Lutz", "This work (unit)", "MiniFloat-NN"] {
            assert!(s.contains(d), "{d} missing");
        }
    }

    #[test]
    fn scaling_table_shape() {
        // A reduced DeiT-shaped workload keeps the sweep fast while
        // exercising the full scale-out path end to end.
        let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
        let pts = scaleout_scaling(&cfg, &[1, 2], 5, false);
        assert_eq!(pts.len(), 2);
        assert!((pts[0].speedup - 1.0).abs() < 1e-12);
        assert!(pts[1].speedup > 1.2, "2 clusters only {}x", pts[1].speedup);
        assert!(pts[1].efficiency <= 1.0 + 1e-9);
        assert!(pts[1].gflops > pts[0].gflops);
        let text = render_scaling(&pts, &cfg);
        assert!(text.contains("clusters"));
        assert!(text.contains("Scale-out"));
    }

    #[test]
    fn format_sweep_covers_all_formats_and_fp4_leads() {
        // 2-core, single-K quick sweep: every format present, FP4 the
        // fastest (16 lanes/issue), FP8 more accurate than FP4.
        let pts = format_sweep(2, 1, &[64]);
        assert_eq!(pts.len(), ElemFormat::ALL.len());
        let g = |fmt| pts.iter().find(|p| p.fmt == fmt).unwrap();
        let f4 = g(ElemFormat::E2M1);
        let f8 = g(ElemFormat::E4M3);
        assert!(f4.gflops > f8.gflops * 1.5, "{} vs {}", f4.gflops, f8.gflops);
        assert!(f8.rel_err < f4.rel_err, "e4m3 should be more accurate than e2m1");
        for p in &pts {
            assert!(p.utilization > 0.2 && p.utilization <= 1.0, "{}: {}", p.fmt, p.utilization);
        }
        let text = render_format_sweep(&pts, 2);
        assert!(text.contains("Format sweep"));
        for fmt in ElemFormat::ALL {
            assert!(text.contains(fmt.name()), "{fmt} missing from table");
        }
    }

    #[test]
    fn pareto_sweep_headline_and_table() {
        // Reduced sequence keeps the cycle-accurate walks and the host
        // forwards fast; shapes stay DeiT-Tiny's widths so the per-K
        // utilization structure is the real one.
        let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
        let pols: Vec<(String, PrecisionPolicy)> = pareto_presets()
            .into_iter()
            .filter(|(n, _)| n == "all-fp8" || n == "fp4-ffn")
            .collect();
        let pts = pareto_sweep(&cfg, &pols, 2, 8, 7, false);
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.gflops() > 0.0 && p.rel_err > 0.0, "{p:?}");
            assert_eq!(p.hw.layers.len(), 4);
        }
        let (thr, err) = pareto_headline(&pts).unwrap();
        // the acceptance bar is ≥ 1.3x on the full DeiT-Tiny shapes
        // (enforced by benches/pareto.rs); the 16-row tiles here pay
        // proportionally more per-pass staging, so allow a little slack
        assert!(thr >= 1.25, "fp4-ffn throughput ratio {thr:.2} below the bar");
        assert!(err > 1.0, "fp4 must cost accuracy: ratio {err:.2}");
        assert!(err < 8.0, "error ratio implausible: {err:.2}");
        let text = render_pareto(&pts, &cfg, 2);
        assert!(text.contains("Pareto"), "{text}");
        assert!(text.contains("fp4-ffn") && text.contains("headline"));
    }

    #[test]
    fn serving_sweep_table_and_headline_bar() {
        // Reduced model keeps the tick horizons short; the engine is
        // analytic, so no cycle-accurate simulation runs here.
        let cfg = ServeConfig {
            model: DeitConfig { seq: 64, ..DeitConfig::default() },
            clusters: 4,
            ..ServeConfig::default()
        };
        let mix = vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)];
        let pts = serving_sweep(&cfg, &mix, 150, 42, &[0.5, 4.0]);
        assert_eq!(pts.len(), 4);
        // every offered request is accounted for on every row
        for p in &pts {
            assert_eq!(p.offered, 150);
            assert_eq!(p.served + p.rejected_full + p.rejected_slo, 150, "{p:?}");
        }
        // at half load both schedulers serve everything within SLO
        let low_cont = pts
            .iter()
            .find(|p| p.load_mult == 0.5 && p.sched == SchedulerKind::Continuous)
            .unwrap();
        assert_eq!(low_cont.served, 150);
        assert!(low_cont.in_slo >= 145, "{low_cont:?}");
        // the §12 acceptance bar: ≥ 1.5× goodput at the top load
        let ratio = serving_headline_ratio(&pts).unwrap();
        assert!(ratio >= 1.5, "continuous/barrier goodput ratio {ratio}");
        let text = render_serving(&pts, &cfg, &mix);
        assert!(text.contains("Serving"), "{text}");
        assert!(text.contains("barrier") && text.contains("continuous"));
        assert!(text.contains("headline"));
    }

    #[test]
    fn fleet_sweep_table_and_headline() {
        // Reduced model keeps the tick horizons short; the fleet engine
        // is analytic end to end, so no cycle simulation runs here.
        let cfg = ServeConfig {
            clusters: 4,
            ..fleet_machine(DeitConfig { seq: 64, ..DeitConfig::default() })
        };
        let pts = fleet_sweep(&cfg, 200, 42, &[1, 3]);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert_eq!(p.offered, 200);
            assert!(p.served <= 200, "{p:?}");
        }
        // one machine: the routers are indistinguishable by construction
        let one: Vec<_> = pts.iter().filter(|p| p.machines == 1).collect();
        assert_eq!(one[0].goodput_per_ktick, one[1].goodput_per_ktick);
        assert_eq!(one[0].reload_ticks, one[1].reload_ticks);
        // three machines, three policy classes: affinity keeps each
        // class resident somewhere and pays strictly fewer reload ticks
        let at = |r: RouterKind| {
            pts.iter().find(|p| p.machines == 3 && p.router == r).unwrap()
        };
        let (aff, rr) = (at(RouterKind::Affinity), at(RouterKind::RoundRobin));
        assert!(
            aff.reload_ticks < rr.reload_ticks,
            "affinity {} vs rr {} reload ticks",
            aff.reload_ticks,
            rr.reload_ticks
        );
        let ratio = fleet_headline_ratio(&pts).unwrap();
        assert!(ratio >= 1.0, "affinity/rr goodput ratio {ratio}");
        let text = render_fleet(&pts, &cfg);
        assert!(text.contains("Fleet"), "{text}");
        assert!(text.contains("affinity") && text.contains("rr"));
        assert!(text.contains("headline"));
    }

    #[test]
    fn fig4_sweep_runs_for_non_fp8_formats_without_sw_baseline() {
        let pts = fig4_sweep(ElemFormat::E2M1, 2, 1);
        assert!(pts.iter().all(|p| p.kind != KernelKind::Fp8ToFp32));
        assert!(pts.iter().any(|p| p.kind == KernelKind::Mx(ElemFormat::E2M1)));
        let text = render_fig4(&pts, ElemFormat::E2M1);
        assert!(text.contains("e2m1"));
        // absent-baseline ratio rows render a dash, not the f64::MAX
        // sentinel (the FP8-SW kernel does not exist for FP4)
        assert!(text.contains("speedup vs FP8-SW        —"), "{text}");
        assert!(!text.contains("17976931"), "sentinel leaked into the headline:\n{text}");
        // the FP32 rows are still real ranges (FP32 runs at K<=128)
        assert!(text.contains("speedup vs FP32"));
    }

    #[test]
    fn fig4_sweep_small_cluster_shape() {
        // 2-core quick sweep: shape must hold (mx > fp32 > sw at K=128).
        let pts = fig4_sweep(ElemFormat::E4M3, 2, 1);
        let g = |k: usize, kind| {
            pts.iter().find(|p| p.k == k && p.kind == kind).map(|p| p.gflops)
        };
        let mx = g(128, KernelKind::Mx(ElemFormat::E4M3)).unwrap();
        let f = g(128, KernelKind::Fp32).unwrap();
        let sw = g(128, KernelKind::Fp8ToFp32).unwrap();
        assert!(mx > f && f > sw, "{mx} {f} {sw}");
        // FP32 absent at 256
        assert!(g(256, KernelKind::Fp32).is_none());
        let text = render_fig4(&pts, ElemFormat::E4M3);
        assert!(text.contains("Fig. 4"));
        assert!(text.contains("headline"));
    }
}
