//! Open-loop arrival-trace generators for the serving engine
//! (DESIGN.md §12): Poisson and bursty request streams with a
//! per-format traffic mix and request priorities.
//!
//! The serving engine (`crate::serve`) is a deterministic
//! discrete-tick simulation; its inputs are *traces* — pre-generated
//! arrival sequences — so every experiment is replayable from a seed
//! and both schedulers under comparison consume the identical offered
//! load. Time is measured in scheduler **ticks** (1 tick = 1 µs of
//! simulated fabric time at the 1 GHz cluster clock, see
//! `serve::CYCLES_PER_TICK`); offered load is quoted in requests per
//! kilotick (≈ requests per simulated millisecond).
//!
//! Two arrival processes are modeled, both *open-loop* (arrivals do
//! not slow down when the server backs up — the production regime the
//! admission controller exists for):
//!
//! * **Poisson** — exponential inter-arrival gaps at the configured
//!   mean rate; the memoryless baseline.
//! * **Bursty** — a Poisson process at `burst_factor ×` the mean rate,
//!   thinned to the first `1/burst_factor` of every `period_ticks`
//!   window. The long-run mean rate matches the Poisson process; the
//!   on-window instantaneous rate is `burst_factor ×` higher — the
//!   flash-crowd pattern that collapses barrier batchers.
//!
//! Formats are drawn per request from a weighted mix (the VMXDOTP
//! mixed-precision traffic scenario), priorities from a Bernoulli
//! draw, both from the same deterministic [`XorShift`] stream.

use crate::formats::ElemFormat;
use crate::model::PrecisionPolicy;
use crate::rng::XorShift;

/// Request priority class. The serving engine schedules
/// [`Priority::High`] classes strictly before [`Priority::Normal`]
/// ones; order *within* a (format, priority) class is always FIFO.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive traffic, scheduled strictly first.
    High,
    /// The default class.
    Normal,
}

impl Priority {
    /// Both priorities, scheduling order (High first).
    pub const ALL: [Priority; 2] = [Priority::High, Priority::Normal];

    /// Dense index (High = 0, Normal = 1) for per-class tables.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
        }
    }
}

/// The arrival process shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at the spec's mean rate.
    Poisson,
    /// On/off bursts: rate `burst_factor ×` the mean inside the first
    /// `1/burst_factor` of every `period_ticks` window, zero outside —
    /// the long-run mean rate equals the spec's rate.
    Bursty {
        /// Burst intensity (≥ 1; 1 degenerates to Poisson).
        burst_factor: f64,
        /// Length of one on/off cycle in ticks.
        period_ticks: u64,
    },
}

/// Full specification of one offered-load trace.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalSpec {
    /// Arrival process shape.
    pub kind: ArrivalKind,
    /// Mean offered load in requests per kilotick (≈ req/ms of
    /// simulated time).
    pub rate_per_ktick: f64,
    /// Weighted element-format mix; weights are relative (they need
    /// not sum to 1) and must be positive.
    pub mix: Vec<(ElemFormat, f64)>,
    /// Fraction of requests tagged [`Priority::High`] (0 disables).
    pub high_priority_frac: f64,
    /// Trace length in requests.
    pub requests: usize,
    /// RNG seed; the trace is a pure function of the spec.
    pub seed: u64,
}

impl ArrivalSpec {
    /// A Poisson spec with a single-format mix and no high-priority
    /// traffic — the smallest useful trace description.
    pub fn poisson(rate_per_ktick: f64, fmt: ElemFormat, requests: usize, seed: u64) -> Self {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate_per_ktick,
            mix: vec![(fmt, 1.0)],
            high_priority_frac: 0.0,
            requests,
            seed,
        }
    }
}

/// One offered request: when it arrives and what it asks for. The
/// request *payload* is derived from `id` downstream (the serving
/// engine seeds `workload::generate_input` with it), so a trace stays
/// a compact description of real work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Trace-order sequence number (also the payload seed offset).
    pub id: u64,
    /// Arrival time in scheduler ticks (non-decreasing along a trace).
    pub tick: u64,
    /// Element format this request advertises (the traffic-mix label;
    /// `policy` is authoritative for cost and execution).
    pub fmt: ElemFormat,
    /// Scheduling class.
    pub priority: Priority,
    /// Per-layer precision policy this request carries (DESIGN.md
    /// §13). Traces generated from a format mix carry
    /// [`PrecisionPolicy::uniform`]`(fmt)` — the single-format recipe —
    /// so a format-mix trace behaves exactly as before the policy
    /// field existed; `mxdotp-cli serve --policy` rewrites it.
    pub policy: PrecisionPolicy,
}

/// Generate a deterministic arrival trace from `spec`.
///
/// Ticks are non-decreasing; ids are 0..requests in arrival order.
/// Panics on a degenerate spec (non-positive rate, empty mix,
/// non-positive weight, burst factor < 1, zero burst period).
pub fn generate_trace(spec: &ArrivalSpec) -> Vec<Arrival> {
    assert!(
        spec.rate_per_ktick > 0.0 && spec.rate_per_ktick.is_finite(),
        "arrival rate must be positive"
    );
    assert!(!spec.mix.is_empty(), "format mix must name at least one format");
    assert!(
        spec.mix.iter().all(|&(_, w)| w > 0.0 && w.is_finite()),
        "format-mix weights must be positive"
    );
    assert!(
        (0.0..=1.0).contains(&spec.high_priority_frac),
        "high-priority fraction must be in [0, 1]"
    );
    let (gen_rate, burst) = match spec.kind {
        ArrivalKind::Poisson => (spec.rate_per_ktick, None),
        ArrivalKind::Bursty { burst_factor, period_ticks } => {
            assert!(burst_factor >= 1.0, "burst factor must be >= 1");
            assert!(period_ticks > 0, "burst period must be positive");
            (spec.rate_per_ktick * burst_factor, Some((burst_factor, period_ticks)))
        }
    };
    let per_tick = gen_rate / 1000.0;
    let total_w: f64 = spec.mix.iter().map(|&(_, w)| w).sum();
    let mut rng = XorShift::new(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.requests);
    while out.len() < spec.requests {
        // Exponential inter-arrival gap at the generator rate.
        let u = rng.unit_f64();
        t += -(1.0 - u).ln() / per_tick;
        let tick = t as u64;
        if let Some((factor, period)) = burst {
            // Thin to the on-window: keep the first 1/factor of each
            // period (so the long-run mean rate is the spec's rate).
            let on_ticks = (period as f64 / factor).max(1.0) as u64;
            if tick % period >= on_ticks {
                continue;
            }
        }
        // Weighted format draw, then the priority Bernoulli — both
        // only for *kept* events, so thinning cannot skew the mix.
        let mut pick = rng.unit_f64() * total_w;
        let mut fmt = spec.mix[0].0;
        for &(f, w) in &spec.mix {
            fmt = f;
            pick -= w;
            if pick <= 0.0 {
                break;
            }
        }
        let priority = if spec.high_priority_frac > 0.0
            && rng.unit_f64() < spec.high_priority_frac
        {
            Priority::High
        } else {
            Priority::Normal
        };
        out.push(Arrival {
            id: out.len() as u64,
            tick,
            fmt,
            priority,
            policy: PrecisionPolicy::uniform(fmt),
        });
    }
    out
}

/// Per-tenant traffic description for the fleet layer (DESIGN.md
/// §17): how the fleet's tenants split an arrival trace. Weights are
/// relative offered-traffic shares (they need not sum to 1); the
/// fair-share admission weights live in the fleet config, not here, so
/// "who sends how much" and "who is entitled to how much" can differ —
/// that gap is exactly the adversarial-overload scenario the fleet
/// property suite pins.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Relative offered-traffic share per tenant id (index = tenant).
    pub weights: Vec<f64>,
    /// Seed of the tagging stream. Deliberately separate from
    /// [`ArrivalSpec::seed`]: tagging draws from its own
    /// [`XorShift`] so it cannot perturb [`generate_trace`]'s draw
    /// order (whose determinism the arrival tests pin).
    pub seed: u64,
}

/// Tag every arrival of `trace` with a tenant id, drawn per request
/// from `spec`'s weighted shares. Returns one tenant id per trace
/// index (parallel to `trace`); a pure function of
/// `(trace.len(), spec)`.
///
/// Panics on an empty or non-positive weight vector.
pub fn assign_tenants(trace: &[Arrival], spec: &TenantSpec) -> Vec<u32> {
    assert!(!spec.weights.is_empty(), "tenant spec must name at least one tenant");
    assert!(
        spec.weights.iter().all(|&w| w > 0.0 && w.is_finite()),
        "tenant weights must be positive"
    );
    let total: f64 = spec.weights.iter().sum();
    let mut rng = XorShift::new(spec.seed);
    trace
        .iter()
        .map(|_| {
            let mut pick = rng.unit_f64() * total;
            let mut tenant = 0u32;
            for (i, &w) in spec.weights.iter().enumerate() {
                tenant = i as u32;
                pick -= w;
                if pick <= 0.0 {
                    break;
                }
            }
            tenant
        })
        .collect()
}

/// Rewrite `trace` in place with a weighted mix of `(format, policy)`
/// traffic classes — the mixed-policy fleet workload (e.g. all-fp8 /
/// fp4-ffn / all-fp4 tenants sharing one fleet). Each request draws one
/// class from its own seeded [`XorShift`] stream (again separate from
/// the trace stream), then carries that class's format label *and*
/// per-layer policy, so per-(format, priority) queues stay
/// policy-uniform and every format transition is a real weight reload.
///
/// Panics on an empty class list or non-positive weight.
pub fn assign_policy_classes(
    trace: &mut [Arrival],
    classes: &[(ElemFormat, PrecisionPolicy, f64)],
    seed: u64,
) {
    assert!(!classes.is_empty(), "class list must name at least one class");
    assert!(
        classes.iter().all(|&(_, _, w)| w > 0.0 && w.is_finite()),
        "class weights must be positive"
    );
    let total: f64 = classes.iter().map(|&(_, _, w)| w).sum();
    let mut rng = XorShift::new(seed);
    for r in trace.iter_mut() {
        let mut pick = rng.unit_f64() * total;
        let (mut fmt, mut policy) = (classes[0].0, classes[0].1);
        for &(f, p, w) in classes {
            fmt = f;
            policy = p;
            pick -= w;
            if pick <= 0.0 {
                break;
            }
        }
        r.fmt = fmt;
        r.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_spec(kind: ArrivalKind) -> ArrivalSpec {
        ArrivalSpec {
            kind,
            rate_per_ktick: 8.0,
            mix: vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)],
            high_priority_frac: 0.25,
            requests: 2000,
            seed: 7,
        }
    }

    #[test]
    fn poisson_trace_is_deterministic_ordered_and_rate_accurate() {
        let spec = mixed_spec(ArrivalKind::Poisson);
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        assert_eq!(a, b, "same spec must yield the identical trace");
        assert_eq!(a.len(), 2000);
        assert!(a.windows(2).all(|w| w[0].tick <= w[1].tick), "ticks must be sorted");
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64));
        // empirical rate within 10 % of the requested 8/ktick
        let span = a.last().unwrap().tick.max(1) as f64;
        let rate = a.len() as f64 * 1000.0 / span;
        assert!((rate - 8.0).abs() / 8.0 < 0.1, "empirical rate {rate}");
    }

    #[test]
    fn mix_and_priority_fractions_are_respected() {
        let a = generate_trace(&mixed_spec(ArrivalKind::Poisson));
        let e4 = a.iter().filter(|r| r.fmt == ElemFormat::E4M3).count() as f64;
        let frac = e4 / a.len() as f64;
        assert!((frac - 0.6).abs() < 0.05, "e4m3 fraction {frac}");
        let hi = a.iter().filter(|r| r.priority == Priority::High).count() as f64;
        let hfrac = hi / a.len() as f64;
        assert!((hfrac - 0.25).abs() < 0.05, "high-priority fraction {hfrac}");
    }

    #[test]
    fn bursty_trace_keeps_the_mean_rate_but_clusters_arrivals() {
        let spec = mixed_spec(ArrivalKind::Bursty { burst_factor: 8.0, period_ticks: 4000 });
        let a = generate_trace(&spec);
        assert_eq!(a.len(), 2000);
        assert!(a.windows(2).all(|w| w[0].tick <= w[1].tick));
        // every kept arrival is inside the on-window
        assert!(a.iter().all(|r| r.tick % 4000 < 500), "arrival outside burst window");
        // long-run mean within 15 % of the spec rate
        let span = a.last().unwrap().tick.max(1) as f64;
        let rate = a.len() as f64 * 1000.0 / span;
        assert!((rate - 8.0).abs() / 8.0 < 0.15, "bursty mean rate {rate}");
    }

    #[test]
    fn generated_arrivals_carry_uniform_policies() {
        // Format-mix traces are single-format per request: every
        // arrival's policy is the uniform recipe of its format, so the
        // serving engine's per-policy accounting degenerates exactly
        // to the per-format behavior for these traces.
        let a = generate_trace(&mixed_spec(ArrivalKind::Poisson));
        assert!(a.iter().all(|r| r.policy == PrecisionPolicy::uniform(r.fmt)));
    }

    #[test]
    #[should_panic(expected = "weights must be positive")]
    fn zero_weight_is_rejected() {
        let mut spec = mixed_spec(ArrivalKind::Poisson);
        spec.mix[1].1 = 0.0;
        generate_trace(&spec);
    }

    #[test]
    fn tenant_tagging_is_deterministic_weighted_and_trace_invisible() {
        let spec = mixed_spec(ArrivalKind::Poisson);
        let trace = generate_trace(&spec);
        let tspec = TenantSpec { weights: vec![0.5, 0.3, 0.2], seed: 11 };
        let a = assign_tenants(&trace, &tspec);
        let b = assign_tenants(&trace, &tspec);
        assert_eq!(a, b, "tagging must be a pure function of (trace len, spec)");
        assert_eq!(a.len(), trace.len());
        // weighted shares land within 5 % of the spec
        for (tenant, &w) in tspec.weights.iter().enumerate() {
            let frac =
                a.iter().filter(|&&t| t == tenant as u32).count() as f64 / a.len() as f64;
            assert!((frac - w).abs() < 0.05, "tenant {tenant} share {frac} vs {w}");
        }
        // tagging draws from its own stream: the trace is untouched
        // and regenerating it yields the identical arrivals
        assert_eq!(trace, generate_trace(&spec));
    }

    #[test]
    fn policy_class_rewrite_is_deterministic_and_weighted() {
        let spec = mixed_spec(ArrivalKind::Poisson);
        let mut a = generate_trace(&spec);
        let mut b = generate_trace(&spec);
        let classes = [
            (ElemFormat::E4M3, PrecisionPolicy::preset("all-fp8").unwrap(), 0.5),
            (ElemFormat::E2M1, PrecisionPolicy::preset("all-fp4").unwrap(), 0.5),
        ];
        assign_policy_classes(&mut a, &classes, 3);
        assign_policy_classes(&mut b, &classes, 3);
        assert_eq!(a, b);
        // format and policy always travel together (queue classes stay
        // policy-uniform, so fleet batches never mix policies)
        for r in &a {
            let class = classes.iter().find(|&&(f, _, _)| f == r.fmt).unwrap();
            assert_eq!(r.policy, class.1);
        }
        let fp8 = a.iter().filter(|r| r.fmt == ElemFormat::E4M3).count() as f64;
        let frac = fp8 / a.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "class share {frac}");
        // arrival times and ids are untouched — only the class changed
        let orig = generate_trace(&spec);
        assert!(a.iter().zip(&orig).all(|(x, y)| x.id == y.id && x.tick == y.tick));
    }
}
