//! DeiT-Tiny-shaped synthetic workload: parameter generation matching
//! `python/compile/model.py`, plus the simulated-hardware cost model
//! the coordinator attaches to every served request.
//!
//! The paper extracts its power traces from DeiT-Tiny quantized to
//! MXFP8 (§IV-A); the shapes here are DeiT-Tiny's (dim 192, 3 heads,
//! MLP ratio 4), with the 197-token sequence padded to 256 (DESIGN.md
//! §2). Parameters are moment-matched synthetic tensors (std 0.02),
//! generated with the same deterministic RNG family as the tests.

pub mod arrivals;

use crate::energy::EnergyModel;
use crate::formats::ElemFormat;
use crate::kernels::{run_mm, MmProblem};
use crate::model::{LayerClass, LayerPrecision, ModelGraph, PrecisionPolicy};
use crate::rng::XorShift;

/// DeiT-Tiny-shaped model configuration (mirror of model.DeiTConfig).
#[derive(Clone, Copy, Debug)]
pub struct DeitConfig {
    /// Sequence length (tokens; DeiT's 197 padded to 256).
    pub seq: usize,
    /// Embedding dimension (192 for DeiT-Tiny).
    pub dim: usize,
    /// Attention heads.
    pub heads: usize,
    /// MLP expansion ratio.
    pub mlp_ratio: usize,
    /// MX element format of the quantized linears.
    pub fmt: ElemFormat,
    /// MX block size.
    pub block_size: usize,
    /// MX blocks per dot-product instruction on every core (1 = scalar
    /// `mxdotp`, 2/4/8 = vector `vmxdotp` at that VL). Results are
    /// bit-identical across values; only the cost models change.
    pub vector_len: u8,
}

impl Default for DeitConfig {
    fn default() -> Self {
        DeitConfig {
            seq: 256,
            dim: 192,
            heads: 3,
            mlp_ratio: 4,
            fmt: ElemFormat::E4M3,
            block_size: 32,
            vector_len: 1,
        }
    }
}

impl DeitConfig {
    /// Hidden width of the MLP (dim × MLP ratio; 768 for DeiT-Tiny).
    pub fn mlp_dim(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    /// Total elements across the four MX-quantized weight matrices
    /// (w_qkv, w_proj, w_fc1, w_fc2) — 12·dim² for DeiT shapes. This
    /// is the volume a serving fabric must *requantize and restage*
    /// when it switches element format (the serving engine's reload
    /// cost, DESIGN.md §12).
    pub fn weight_elems(&self) -> u64 {
        self.param_specs()
            .iter()
            .filter(|(name, _)| name.starts_with("w_"))
            .map(|(_, shape)| shape.iter().product::<usize>() as u64)
            .sum()
    }

    /// Elements of the weight matrix one layer class stages (0 for the
    /// weightless attention GEMMs) — the per-layer unit of the serving
    /// engine's format-switch reload accounting (DESIGN.md §13):
    /// switching a fabric between two policies requantizes and
    /// restages only the layers whose format actually changed.
    pub fn layer_weight_elems(&self, class: LayerClass) -> u64 {
        let Some(name) = class.weight_name() else { return 0 };
        self.param_specs()
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, shape)| shape.iter().product::<usize>() as u64)
            .unwrap_or(0)
    }

    /// Parameter (name, shape) list — MUST stay in sync with
    /// `model.param_specs` (the Rust side feeds PJRT in this order).
    pub fn param_specs(&self) -> Vec<(&'static str, Vec<usize>)> {
        let d = self.dim;
        let md = self.mlp_dim();
        vec![
            ("ln1_gamma", vec![d]),
            ("ln1_beta", vec![d]),
            ("w_qkv", vec![d, 3 * d]),
            ("b_qkv", vec![3 * d]),
            ("w_proj", vec![d, d]),
            ("b_proj", vec![d]),
            ("ln2_gamma", vec![d]),
            ("ln2_beta", vec![d]),
            ("w_fc1", vec![d, md]),
            ("b_fc1", vec![md]),
            ("w_fc2", vec![md, d]),
            ("b_fc2", vec![d]),
        ]
    }

    /// The five MX-quantized matmuls of one encoder block, as MM
    /// problems (QKV, attention-out, fc1, fc2; attention internals stay
    /// FP32 — same recipe as the Python model).
    pub fn mx_matmuls(&self) -> Vec<MmProblem> {
        let (s, d, md) = (self.seq, self.dim, self.mlp_dim());
        vec![
            MmProblem { m: s, k: d, n: 3 * d, fmt: self.fmt, block_size: self.block_size },
            MmProblem { m: s, k: d, n: d, fmt: self.fmt, block_size: self.block_size },
            MmProblem { m: s, k: d, n: md, fmt: self.fmt, block_size: self.block_size },
            MmProblem { m: s, k: md, n: d, fmt: self.fmt, block_size: self.block_size },
        ]
    }

    /// Total MX-matmul FLOPs per forward pass.
    pub fn mx_flops(&self) -> u64 {
        self.mx_matmuls().iter().map(|p| p.flops()).sum()
    }
}

/// Generate the flat parameter tensors (moment-matched synthetic).
pub fn generate_params(cfg: &DeitConfig, seed: u64) -> Vec<(String, Vec<usize>, Vec<f32>)> {
    let mut rng = XorShift::new(seed);
    cfg.param_specs()
        .into_iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data = if name.ends_with("gamma") {
                vec![1.0f32; n]
            } else if name.ends_with("beta") || name.starts_with("b_") {
                vec![0.0f32; n]
            } else {
                rng.normal_vec(n, 0.02)
            };
            (name.to_string(), shape, data)
        })
        .collect()
}

/// Generate one input activation (seq × dim).
pub fn generate_input(cfg: &DeitConfig, seed: u64) -> Vec<f32> {
    XorShift::new(seed).normal_vec(cfg.seq * cfg.dim, 0.5)
}

/// Hardware cost of one forward pass on the simulated cluster.
#[derive(Clone, Copy, Debug, Default)]
pub struct HwCost {
    /// Simulated cluster cycles for the MX matmuls.
    pub cycles: u64,
    /// Simulated energy (µJ).
    pub energy_uj: f64,
    /// Equivalent wall-clock at the cluster's 1 GHz (µs).
    pub time_us: f64,
    /// Useful FLOPs.
    pub flops: u64,
}

/// Synthetic per-cluster counters with the MX hardware kernel's
/// activity mix at the format's lane width (one `mxdotp` per
/// `2·lanes` FLOPs; ft0/unroll + ft1 + ft2/4 SSR words ≈ the FP8 mix),
/// split evenly across `num_cores` — the input both analytic cost
/// models feed to the [`EnergyModel`].
fn synthetic_mx_perf(
    fmt: ElemFormat,
    flops: u64,
    num_cores: usize,
    cycles: u64,
) -> crate::snitch::cluster::PerfCounters {
    let mut perf = crate::snitch::cluster::PerfCounters { cycles, ..Default::default() };
    let mxdotp = flops / (2 * fmt.hw_lanes() as u64);
    let fpu = crate::snitch::fpu::FpuCounters {
        mxdotp,
        issued: mxdotp,
        ssr_words: mxdotp * 9 / 8 + mxdotp / 4, // ft0/8 + ft1 + ft2/4
        ..Default::default()
    };
    perf.fpu = vec![fpu; num_cores.max(1)];
    // fpu counters above are totals split across cores; rescale
    for f in perf.fpu.iter_mut() {
        f.mxdotp /= num_cores as u64;
        f.issued /= num_cores as u64;
        f.ssr_words /= num_cores as u64;
    }
    perf
}

/// Analytic cost model: cycles ≈ FLOPs / (2·lanes·VL FLOP/cycle/core ×
/// cores × utilization(K)) at the workload's element format (16
/// FLOPs/cycle/core for the byte-wide formats, 32 for MXFP4, ×VL when
/// the vector `vmxdotp` kernel is selected via
/// [`DeitConfig::vector_len`]). `calibrated_util` comes from a measured
/// kernel run of the *same* VL (see [`calibrate_util`]), so the
/// product `ideal·util` is the calibration run's measured throughput
/// either way; energy from the EnergyModel's MX operating point.
pub fn analytic_cost(cfg: &DeitConfig, num_cores: usize, calibrated_util: f64) -> HwCost {
    let flops = cfg.mx_flops();
    let ideal = 2.0
        * cfg.fmt.hw_lanes() as f64
        * cfg.vector_len.max(1) as f64
        * num_cores as f64;
    let cycles = (flops as f64 / (ideal * calibrated_util)) as u64;
    // power at the calibrated MX operating point (see EnergyModel):
    // derive from a synthetic counter set with the same activity mix.
    let em = EnergyModel;
    let perf = synthetic_mx_perf(cfg.fmt, flops, num_cores, cycles);
    let p = em.power(&perf, 1.0, true);
    HwCost {
        cycles,
        energy_uj: p.energy_uj,
        time_us: cycles as f64 / 1000.0,
        flops,
    }
}

/// Hardware cost of one forward pass sharded across a cluster fabric,
/// with the per-cluster breakdown the scale-out engine reports.
#[derive(Clone, Debug, Default)]
pub struct ShardedHwCost {
    /// Fabric totals: `cycles` is the wall-clock model (max over
    /// clusters), `energy_uj` the sum across clusters.
    pub total: HwCost,
    /// Per-cluster costs (`cycles` = that cluster's busy window).
    pub per_cluster: Vec<HwCost>,
    /// Per-layer-class breakdown when built by the policy-aware
    /// [`analytic_policy_sharded_cost`] (each entry's `cycles` is that
    /// layer's sharded wall share); empty for the single-format
    /// [`analytic_sharded_cost`] entry point.
    pub per_layer: Vec<(LayerClass, HwCost)>,
}

/// Analytic scale-out cost model: the serial single-cluster cost of
/// [`analytic_cost`] divided across `clusters` at a measured
/// `parallel_eff` (strong-scaling efficiency from
/// `scaleout::measure_parallel_efficiency`). Each cluster stays powered
/// for the whole fabric wall-clock, so total energy *rises* as
/// efficiency falls — the fabric idle floor is N clusters wide.
pub fn analytic_sharded_cost(
    cfg: &DeitConfig,
    num_cores: usize,
    calibrated_util: f64,
    clusters: usize,
    parallel_eff: f64,
) -> ShardedHwCost {
    let clusters = clusters.max(1);
    let serial = analytic_cost(cfg, num_cores, calibrated_util);
    if clusters == 1 {
        return ShardedHwCost { total: serial, per_cluster: vec![serial], per_layer: Vec::new() };
    }
    let eff = parallel_eff.clamp(0.05, 1.0);
    let wall = ((serial.cycles as f64) / (clusters as f64 * eff)).ceil() as u64;
    let em = EnergyModel;
    let flops_per = cfg.mx_flops() / clusters as u64;
    let mut per_cluster = Vec::with_capacity(clusters);
    let mut total_energy = 0.0;
    for _ in 0..clusters {
        let perf = synthetic_mx_perf(cfg.fmt, flops_per, num_cores, wall);
        let e = em.power(&perf, 1.0, true).energy_uj;
        total_energy += e;
        per_cluster.push(HwCost {
            cycles: wall,
            energy_uj: e,
            time_us: wall as f64 / 1000.0,
            flops: flops_per,
        });
    }
    ShardedHwCost {
        total: HwCost {
            cycles: wall,
            energy_uj: total_energy,
            time_us: wall as f64 / 1000.0,
            flops: cfg.mx_flops(),
        },
        per_cluster,
        per_layer: Vec::new(),
    }
}

/// Per-layer-class MX GEMM FLOPs of one forward pass, indexed by
/// `LayerClass::index()` — precompute once and price policies through
/// [`analytic_policy_cycles_from`] on hot paths (the serving engine's
/// per-arrival costing) instead of rebuilding the graph per call.
pub fn layer_flops_table(cfg: &DeitConfig) -> [u64; 6] {
    let graph = ModelGraph::deit_block(cfg);
    let mut table = [0u64; 6];
    for node in &graph.nodes {
        table[node.class.index()] = node.flops();
    }
    table
}

/// Serial (single-cluster) analytic cycles of one forward pass under a
/// per-layer precision policy: the policy's MX FLOPs grouped by
/// element format, each group billed at its format's lane width —
/// `cycles_g = flops_g / (2·lanes·cores·utilization)` — and summed.
///
/// For a [`PrecisionPolicy::uniform`] policy this reduces to exactly
/// the single group of [`analytic_cost`], bit-for-bit (the serving
/// cost model's uniform-policy compatibility depends on it).
pub fn analytic_policy_cycles(
    cfg: &DeitConfig,
    policy: &PrecisionPolicy,
    num_cores: usize,
    calibrated_util: f64,
) -> u64 {
    analytic_policy_cycles_from(
        &layer_flops_table(cfg),
        policy,
        num_cores,
        calibrated_util,
        cfg.vector_len,
    )
}

/// [`analytic_policy_cycles`] from a precomputed [`layer_flops_table`]
/// — allocation-free, so the serving engine can price every arriving
/// request's policy without rebuilding the model graph. `vector_len`
/// is the fabric-wide VL (every format group runs the same kernel
/// family; 1 bills the scalar `mxdotp` lane width).
pub fn analytic_policy_cycles_from(
    layer_flops: &[u64; 6],
    policy: &PrecisionPolicy,
    num_cores: usize,
    calibrated_util: f64,
    vector_len: u8,
) -> u64 {
    let mut per_fmt = [0u64; 6];
    for class in LayerClass::ALL {
        if let LayerPrecision::Mx(f) = policy.get(class) {
            per_fmt[f.csr_code() as usize] += layer_flops[class.index()];
        }
    }
    let vl = vector_len.max(1) as f64;
    let mut cycles = 0u64;
    for fmt in ElemFormat::ALL {
        let flops = per_fmt[fmt.csr_code() as usize];
        if flops == 0 {
            continue;
        }
        let ideal = 2.0 * fmt.hw_lanes() as f64 * vl * num_cores as f64;
        cycles += (flops as f64 / (ideal * calibrated_util)) as u64;
    }
    cycles
}

/// Policy-aware analytic scale-out cost: the per-layer mixed-precision
/// counterpart of [`analytic_sharded_cost`], with a per-layer-class
/// breakdown in [`ShardedHwCost::per_layer`].
///
/// Uniform policies delegate to [`analytic_sharded_cost`] (identical
/// totals, so the serving engine's numbers cannot drift when every
/// request still carries a single-format policy); mixed policies bill
/// each format group at its lane width and sum the groups' energies at
/// the calibrated MX operating point.
pub fn analytic_policy_sharded_cost(
    cfg: &DeitConfig,
    policy: &PrecisionPolicy,
    num_cores: usize,
    calibrated_util: f64,
    clusters: usize,
    parallel_eff: f64,
) -> ShardedHwCost {
    let clusters = clusters.max(1);
    let graph = ModelGraph::deit_block(cfg);
    let eff = if clusters > 1 { parallel_eff.clamp(0.05, 1.0) } else { 1.0 };
    let shard = |serial: u64| -> u64 {
        if clusters == 1 {
            serial
        } else {
            ((serial as f64) / (clusters as f64 * eff)).ceil() as u64
        }
    };
    // Per-layer breakdown (each layer's own sharded wall share).
    let em = EnergyModel;
    let mut per_layer = Vec::new();
    let vl = cfg.vector_len.max(1) as f64;
    for node in &graph.nodes {
        let LayerPrecision::Mx(fmt) = policy.get(node.class) else { continue };
        let flops = node.flops();
        let ideal = 2.0 * fmt.hw_lanes() as f64 * vl * num_cores as f64;
        let serial = (flops as f64 / (ideal * calibrated_util)) as u64;
        let wall = shard(serial);
        let perf = synthetic_mx_perf(fmt, flops / clusters as u64, num_cores, wall);
        let energy = clusters as f64 * em.power(&perf, 1.0, true).energy_uj;
        per_layer.push((
            node.class,
            HwCost { cycles: wall, energy_uj: energy, time_us: wall as f64 / 1000.0, flops },
        ));
    }
    let mut cost = if let Some(fmt) = policy.uniform_fmt() {
        // Exact compatibility with the single-format path.
        analytic_sharded_cost(
            &DeitConfig { fmt, ..*cfg },
            num_cores,
            calibrated_util,
            clusters,
            parallel_eff,
        )
    } else {
        let serial = analytic_policy_cycles(cfg, policy, num_cores, calibrated_util);
        let wall = shard(serial);
        let energy: f64 = per_layer.iter().map(|(_, c)| c.energy_uj).sum();
        let flops = graph.mx_flops(policy);
        let total =
            HwCost { cycles: wall, energy_uj: energy, time_us: wall as f64 / 1000.0, flops };
        let per_cluster = vec![
            HwCost {
                cycles: wall,
                energy_uj: energy / clusters as f64,
                time_us: wall as f64 / 1000.0,
                flops: flops / clusters as u64,
            };
            clusters
        ];
        ShardedHwCost { total, per_cluster, per_layer: Vec::new() }
    };
    cost.per_layer = per_layer;
    cost
}

/// Measure real MXFP8 utilization on a representative layer (fc1) by
/// running the full cycle-accurate simulator once; the coordinator
/// uses the result to calibrate [`analytic_cost`].
///
/// Warm path by default: the calibration GEMM plans through the
/// process-wide [`PlanCache`](crate::kernels::plan::PlanCache), so a
/// server that re-calibrates per batch/restart-of-serving pays the
/// simulation once per (shape, seed) and hits the memoized pass after
/// that. `cold_plans` (the CLI's `--cold-plans`) forces a from-scratch
/// run; the measured utilization is identical either way because the
/// simulation is deterministic.
pub fn calibrate_util(cfg: &DeitConfig, num_cores: usize, seed: u64, cold_plans: bool) -> f64 {
    // fc1 shape is the largest; use a K-truncated version to keep the
    // calibration run fast while exercising the same inner structure.
    let p = MmProblem { m: 64, k: cfg.dim, n: 64, fmt: cfg.fmt, block_size: cfg.block_size };
    let mut rng = XorShift::new(seed);
    let a = rng.normal_vec(p.m * p.k, 0.5);
    let b = rng.normal_vec(p.k * p.n, 0.02);
    // The kernel under calibration follows the configured VL: a vector
    // fabric must calibrate against the vector kernel (utilization is
    // measured relative to the VL-scaled ideal, so `ideal·util` stays
    // the measured throughput in both worlds).
    let kind = p.vmx_kernel(cfg.vector_len);
    if cold_plans {
        return run_mm(kind, p, &a, &b, num_cores).utilization();
    }
    let mut cluster = crate::snitch::cluster::Cluster::new(
        crate::snitch::cluster::ClusterConfig { num_cores, freq_ghz: 1.0 },
    );
    let run = crate::kernels::plan::run_mm_cached(
        crate::kernels::plan::PlanCache::global(),
        &mut cluster,
        kind,
        p,
        &a,
        &b,
    );
    run.utilization()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_specs_match_python_layout() {
        let cfg = DeitConfig::default();
        let specs = cfg.param_specs();
        assert_eq!(specs.len(), 12);
        assert_eq!(specs[2], ("w_qkv", vec![192, 576]));
        assert_eq!(specs[10], ("w_fc2", vec![768, 192]));
        let total: usize = specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        // DeiT-Tiny per-block parameter count
        assert_eq!(total, 192 * 576 + 576 + 192 * 192 + 192 + 192 * 768 + 768 + 768 * 192 + 192 + 4 * 192);
    }

    #[test]
    fn generated_params_are_deterministic_and_shaped() {
        let cfg = DeitConfig::default();
        let p1 = generate_params(&cfg, 42);
        let p2 = generate_params(&cfg, 42);
        for ((n1, s1, d1), (n2, s2, d2)) in p1.iter().zip(&p2) {
            assert_eq!(n1, n2);
            assert_eq!(s1, s2);
            assert_eq!(d1, d2);
        }
        let w_qkv = &p1[2].2;
        let mean: f32 = w_qkv.iter().sum::<f32>() / w_qkv.len() as f32;
        assert!(mean.abs() < 0.001);
    }

    #[test]
    fn weight_elems_is_12_dim_squared() {
        let cfg = DeitConfig::default();
        assert_eq!(cfg.weight_elems(), 12 * 192 * 192);
    }

    #[test]
    fn layer_weight_elems_partition_the_total() {
        let cfg = DeitConfig::default();
        let per: u64 =
            LayerClass::ALL.iter().map(|&c| cfg.layer_weight_elems(c)).sum();
        assert_eq!(per, cfg.weight_elems());
        assert_eq!(cfg.layer_weight_elems(LayerClass::Qkv), 3 * 192 * 192);
        assert_eq!(cfg.layer_weight_elems(LayerClass::AttnScores), 0);
        assert_eq!(cfg.layer_weight_elems(LayerClass::AttnContext), 0);
        assert_eq!(cfg.layer_weight_elems(LayerClass::MlpUp), 4 * 192 * 192);
    }

    #[test]
    fn uniform_policy_cycles_match_the_single_format_model_exactly() {
        let cfg = DeitConfig::default();
        for fmt in ElemFormat::ALL {
            let c = DeitConfig { fmt, ..cfg };
            let serial = analytic_cost(&c, 8, 0.75).cycles;
            let policy = PrecisionPolicy::uniform(fmt);
            assert_eq!(
                analytic_policy_cycles(&c, &policy, 8, 0.75),
                serial,
                "{fmt}: uniform policy must reproduce analytic_cost bit-for-bit"
            );
            let sharded = analytic_sharded_cost(&c, 8, 0.75, 4, 0.9);
            let psharded = analytic_policy_sharded_cost(&c, &policy, 8, 0.75, 4, 0.9);
            assert_eq!(psharded.total.cycles, sharded.total.cycles);
            assert_eq!(psharded.total.energy_uj, sharded.total.energy_uj);
            assert_eq!(psharded.per_layer.len(), 4, "four MX linears under uniform");
        }
    }

    #[test]
    fn fp4_ffn_policy_cost_sits_between_fp8_and_fp4() {
        let cfg = DeitConfig::default();
        let fp8 = analytic_policy_cycles(&cfg, &PrecisionPolicy::uniform(ElemFormat::E4M3), 8, 0.75);
        let fp4 = analytic_policy_cycles(&cfg, &PrecisionPolicy::uniform(ElemFormat::E2M1), 8, 0.75);
        let mixed = analytic_policy_cycles(
            &cfg,
            &PrecisionPolicy::preset("fp4-ffn").unwrap(),
            8,
            0.75,
        );
        assert!(fp4 < mixed && mixed < fp8, "{fp4} < {mixed} < {fp8}");
        // FFN = 2/3 of the FLOPs at double rate: mixed = 2/3 · fp8
        let want = fp8 as f64 * 2.0 / 3.0;
        assert!((mixed as f64 - want).abs() / want < 0.01, "mixed {mixed} vs want {want}");
        // the analytic throughput bar behind `reproduce pareto`
        assert!(fp8 as f64 / mixed as f64 >= 1.3);
    }

    #[test]
    fn mixed_policy_sharded_cost_breaks_down_per_layer() {
        let cfg = DeitConfig::default();
        let policy = PrecisionPolicy::preset("fp4-ffn").unwrap();
        let c = analytic_policy_sharded_cost(&cfg, &policy, 8, 0.75, 4, 0.9);
        assert_eq!(c.per_layer.len(), 4);
        assert!(c.total.cycles > 0 && c.total.energy_uj > 0.0);
        // layer walls sum to ~the fabric wall (per-layer ceil rounding)
        let sum: u64 = c.per_layer.iter().map(|(_, l)| l.cycles).sum();
        assert!(sum >= c.total.cycles && sum <= c.total.cycles + 4, "{sum} vs {}", c.total.cycles);
        // flops across layers partition the policy flops
        let flops: u64 = c.per_layer.iter().map(|(_, l)| l.flops).sum();
        assert_eq!(flops, cfg.mx_flops());
    }

    #[test]
    fn flop_accounting() {
        let cfg = DeitConfig::default();
        let s = 256u64;
        let d = 192u64;
        let want = 2 * s * d * 3 * d + 2 * s * d * d + 2 * s * d * 4 * d + 2 * s * 4 * d * d;
        assert_eq!(cfg.mx_flops(), want);
    }

    #[test]
    fn analytic_cost_sane() {
        let cfg = DeitConfig::default();
        let c = analytic_cost(&cfg, 8, 0.75);
        assert!(c.cycles > 0);
        assert!(c.energy_uj > 0.0);
        // sanity: cycles ~ flops / (16*8*0.75)
        let want = cfg.mx_flops() as f64 / 96.0;
        assert!((c.cycles as f64 - want).abs() / want < 0.01);
    }

    #[test]
    fn analytic_cost_follows_format_lane_width() {
        // MXFP4's 16 lanes/issue double the ideal rate: the analytic
        // wall-clock halves at equal utilization.
        let f8 = analytic_cost(&DeitConfig::default(), 8, 0.75);
        let f4cfg = DeitConfig { fmt: ElemFormat::E2M1, ..DeitConfig::default() };
        let f4 = analytic_cost(&f4cfg, 8, 0.75);
        let ratio = f8.cycles as f64 / f4.cycles as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
        assert_eq!(f8.flops, f4.flops);
    }

    #[test]
    fn analytic_cost_scales_with_vector_length() {
        // At equal calibrated utilization a VL=8 fabric's ideal rate is
        // 8× the scalar one, so the analytic wall shrinks 8×; the
        // policy path must agree with the single-format path at any VL.
        let scalar = analytic_cost(&DeitConfig::default(), 8, 0.75);
        let vcfg = DeitConfig { vector_len: 8, ..DeitConfig::default() };
        let vec8 = analytic_cost(&vcfg, 8, 0.75);
        let ratio = scalar.cycles as f64 / vec8.cycles as f64;
        assert!((ratio - 8.0).abs() < 0.01, "ratio {ratio}");
        assert_eq!(scalar.flops, vec8.flops);
        let fp8 = PrecisionPolicy::uniform(vcfg.fmt);
        assert_eq!(analytic_policy_cycles(&vcfg, &fp8, 8, 0.75), vec8.cycles);
    }

    #[test]
    fn vector_calibration_measures_the_vector_kernel() {
        // VL=8 calibration runs the vmxdotp kernel: utilization is
        // measured against the 8×-wider ideal, so it lands lower than
        // the scalar kernel's but the implied throughput (ideal·util)
        // must be higher — that is what the ≥4× headline measures.
        let cfg = DeitConfig::default();
        let vcfg = DeitConfig { vector_len: 8, ..cfg };
        let us = calibrate_util(&cfg, 4, 1, true);
        let uv = calibrate_util(&vcfg, 4, 1, true);
        assert!(uv > 0.0 && uv < 1.0, "vector util {uv}");
        assert!(uv < us, "vector util {uv} not below scalar {us}");
        assert!(8.0 * uv > us, "vector throughput did not beat scalar: {uv} vs {us}");
        // warm path is the same deterministic simulation
        assert_eq!(calibrate_util(&vcfg, 4, 1, false), uv);
    }

    #[test]
    fn sharded_cost_scales_wall_and_energy() {
        let cfg = DeitConfig::default();
        let serial = analytic_cost(&cfg, 8, 0.75);
        let sharded = analytic_sharded_cost(&cfg, 8, 0.75, 4, 0.9);
        assert_eq!(sharded.per_cluster.len(), 4);
        // wall shrinks by clusters × efficiency
        let want = serial.cycles as f64 / (4.0 * 0.9);
        assert!((sharded.total.cycles as f64 - want).abs() < 2.0);
        // per-cluster wall == fabric wall; flops partition
        for c in &sharded.per_cluster {
            assert_eq!(c.cycles, sharded.total.cycles);
        }
        assert_eq!(
            sharded.per_cluster.iter().map(|c| c.flops).sum::<u64>(),
            cfg.mx_flops() / 4 * 4
        );
        // the N-wide idle floor makes total energy >= the serial energy
        assert!(sharded.total.energy_uj >= serial.energy_uj * 0.99);
        // one cluster degenerates to the serial model
        let one = analytic_sharded_cost(&cfg, 8, 0.75, 1, 1.0);
        assert_eq!(one.total.cycles, serial.cycles);
        assert_eq!(one.per_cluster.len(), 1);
    }

    #[test]
    fn calibration_runs_and_warm_matches_cold() {
        let cfg = DeitConfig::default();
        let u = calibrate_util(&cfg, 4, 1, true);
        assert!(u > 0.3 && u < 1.0, "util {u}");
        // warm path is the same deterministic simulation
        let w = calibrate_util(&cfg, 4, 1, false);
        assert_eq!(u, w);
        // and a repeat hits the memoized pass with the identical value
        assert_eq!(calibrate_util(&cfg, 4, 1, false), w);
    }
}
