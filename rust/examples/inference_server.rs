//! End-to-end driver (the mandated E2E validation): serve a DeiT-Tiny-
//! shaped encoder block — compiled AOT from JAX+Pallas to HLO and
//! loaded through PJRT — behind the batching coordinator, with the
//! per-request hardware cost simulated on the cycle-accurate
//! MXDOTP-extended Snitch cluster.
//!
//! All three layers compose here:
//!   L1 Pallas MX kernel  → inside the HLO artifact,
//!   L2 JAX encoder block → `artifacts/model.hlo.txt`,
//!   L3 Rust coordinator  → queue, batcher, PJRT execution, HW costing.
//!
//! ```sh
//! make artifacts && cargo run --release --example inference_server [requests] [batch]
//! ```
//!
//! Results of a reference run are recorded in EXPERIMENTS.md.

use anyhow::{bail, Result};
use mxdotp::coordinator::{BatchPolicy, Coordinator, PjrtExecutor, Request};
use mxdotp::runtime::Runtime;
use mxdotp::snitch;
use mxdotp::workload::{calibrate_util, generate_input, generate_params, DeitConfig};
use std::time::Instant;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let max_batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    let dir = std::path::Path::new("artifacts");
    if !Runtime::artifacts_present(dir) {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let rt = Runtime::new(dir)?;
    let cfg = DeitConfig::default();
    println!(
        "== MXDOTP inference server ==\n\
         model: DeiT-Tiny-shaped encoder block (seq {}, dim {}, heads {}, MXFP8 {})\n\
         backend: PJRT {} | HW cost: simulated {}-core Snitch+MXDOTP cluster\n",
        cfg.seq,
        cfg.dim,
        cfg.heads,
        cfg.fmt,
        rt.platform(),
        snitch::NUM_CORES
    );

    // L2/L1: load the AOT artifact; parameters mirror the Python specs.
    let t_load = Instant::now();
    let params = generate_params(&cfg, 42);
    let exec = PjrtExecutor::new(&rt, cfg, params)?;
    println!("artifact compiled in {:.2} s", t_load.elapsed().as_secs_f64());

    // Calibrate the analytic HW-cost model with one real simulator run.
    let t_cal = Instant::now();
    let util = calibrate_util(&cfg, snitch::NUM_CORES, 1, false);
    println!(
        "calibrated MXFP8 utilization: {:.1} % (cycle-accurate run, {:.2} s)\n",
        util * 100.0,
        t_cal.elapsed().as_secs_f64()
    );

    let mut coord = Coordinator::new(
        cfg,
        BatchPolicy { max_batch, max_wait_ticks: 4 },
        exec,
        util,
    );

    // Submit a bursty request pattern and drive the scheduler.
    let t0 = Instant::now();
    let mut responses = Vec::new();
    let mut submitted = 0u64;
    while submitted < n_requests || coord.pending() > 0 {
        // bursts of up to 3 requests per tick
        let burst = (n_requests - submitted).min(3);
        for _ in 0..burst {
            coord.submit(Request { id: submitted, input: generate_input(&cfg, 1000 + submitted) });
            submitted += 1;
        }
        responses.extend(coord.tick()?);
    }
    responses.extend(coord.drain()?);
    let wall = t0.elapsed().as_secs_f64();

    // Validate outputs.
    assert_eq!(responses.len() as u64, n_requests);
    for r in &responses {
        assert_eq!(r.output.len(), cfg.seq * cfg.dim);
        assert!(r.output.iter().all(|v| v.is_finite()), "non-finite output in req {}", r.id);
    }

    let mut lat: Vec<f64> = responses.iter().map(|r| r.latency_us).collect();
    lat.sort_by(f64::total_cmp);
    let st = coord.stats;
    println!("== results ==");
    println!(
        "served {} requests in {} batches (mean batch size {:.2}) in {:.3} s",
        st.served,
        st.batches,
        st.mean_batch_size(),
        wall
    );
    println!(
        "host throughput: {:.1} req/s   latency p50/p95/max: {:.0}/{:.0}/{:.0} µs",
        st.served as f64 / wall,
        percentile(&lat, 0.50),
        percentile(&lat, 0.95),
        percentile(&lat, 1.0)
    );
    let per_req = st.total_sim_cycles as f64 / st.served as f64;
    println!(
        "simulated hardware (per request): {:.0} cycles = {:.1} µs @1 GHz, {:.2} µJ",
        per_req,
        per_req / 1000.0,
        st.total_sim_energy_uj / st.served as f64
    );
    println!(
        "simulated cluster totals: {:.2} ms busy, {:.1} µJ ({:.1} mW avg at that duty)",
        st.total_sim_cycles as f64 / 1e6,
        st.total_sim_energy_uj,
        st.total_sim_energy_uj / (st.total_sim_cycles as f64 / 1e9) / 1e3
    );
    println!(
        "\nMX matmul FLOPs per forward: {:.1} MFLOP -> simulated {:.1} GFLOPS effective",
        cfg.mx_flops() as f64 / 1e6,
        cfg.mx_flops() as f64 / (per_req * 1e-9) / 1e9
    );
    Ok(())
}
