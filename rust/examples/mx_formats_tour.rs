//! A tour of the OCP Microscaling formats: every element format of the
//! v1.0 spec, its range/precision trade-off, quantization error by
//! distribution, and what the shared exponent buys over plain FP8.
//!
//! ```sh
//! cargo run --release --example mx_formats_tour
//! ```

use mxdotp::formats::{ElemFormat, MxVector};
use mxdotp::rng::XorShift;

fn quant_snr_db(data: &[f32], fmt: ElemFormat, block: usize) -> f64 {
    let q = MxVector::quantize(data, fmt, block).dequantize();
    let sig: f64 = data.iter().map(|&v| (v as f64).powi(2)).sum();
    let err: f64 = data.iter().zip(&q).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
    10.0 * (sig / err.max(1e-300)).log10()
}

fn main() {
    println!("== the OCP MX v1.0 element formats ==\n");
    println!("  format  bits  emax  max value   min subnormal  values/binade");
    for fmt in ElemFormat::ALL {
        let (minsub, per_binade) = match fmt.float_spec() {
            Some(s) => (format!("{:.2e}", s.min_subnormal()), (1u32 << s.mbits).to_string()),
            None => ("2^-6 grid".to_string(), "—".to_string()),
        };
        println!(
            "  {:<7} {:<5} {:<5} {:<11.5} {:<14} {}",
            fmt.name(),
            fmt.bits(),
            fmt.emax(),
            fmt.max_value(),
            minsub,
            per_binade
        );
    }

    println!("\n== quantization SNR by data distribution (block 32) ==\n");
    let mut rng = XorShift::new(7);
    let n = 4096;
    let normal = rng.normal_vec(n, 1.0);
    let wide: Vec<f32> = (0..n)
        .map(|_| rng.normal_f32() * (2.0f32).powi(rng.range_i64(-12, 12) as i32))
        .collect();
    let activations: Vec<f32> = normal.iter().map(|v| v.max(0.0) * 3.0).collect(); // relu-like
    println!("  distribution      e5m2     e4m3     e3m2     e2m3     e2m1     int8");
    for (name, data) in [("normal(0,1)", &normal), ("wide dynamic", &wide), ("relu acts", &activations)] {
        print!("  {name:<16}");
        for fmt in ElemFormat::ALL {
            print!(" {:7.1}", quant_snr_db(data, fmt, 32));
        }
        println!(" dB");
    }

    println!("\n== what the block scale buys: MX vs per-tensor scaling ==\n");
    // Two regions with very different magnitude in one tensor.
    let mut mixed = rng.normal_vec(2048, 100.0);
    mixed.extend(rng.normal_vec(2048, 0.01));
    // per-tensor: one scale for everything == block size 4096
    let snr_tensor = quant_snr_db(&mixed, ElemFormat::E4M3, 4096);
    let snr_mx = quant_snr_db(&mixed, ElemFormat::E4M3, 32);
    println!("  e4m3, mixed-magnitude tensor:");
    println!("    per-tensor scale (block 4096): {snr_tensor:6.1} dB");
    println!("    MX block-32 scales:            {snr_mx:6.1} dB");
    println!("    -> fine-grained scales preserve the small-magnitude half");

    println!("\n== block size ablation (e4m3, wide dynamic range data) ==\n");
    print!("  block size:");
    for bs in [8usize, 16, 32, 64, 128] {
        print!("  {bs:>5}");
    }
    print!("\n  SNR (dB):  ");
    for bs in [8usize, 16, 32, 64, 128] {
        print!("  {:5.1}", quant_snr_db(&wide, ElemFormat::E4M3, bs));
    }
    println!("\n  (the spec's 32 balances scale overhead vs range tracking)");
}
