//! Quickstart: quantize matrices to MXFP8, multiply them three ways —
//! the bit-accurate MXDOTP datapath, the spec's FP32 reference, and
//! the full cycle-accurate cluster — and compare against FP32.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mxdotp::dotp::MxDotpUnit;
use mxdotp::formats::{dot, ElemFormat, MxMatrix, MxVector, ScaleAxis};
use mxdotp::kernels::{run_mm, KernelKind, MmProblem};
use mxdotp::report::render_run;
use mxdotp::rng::XorShift;

fn main() {
    let mut rng = XorShift::new(2024);

    // --- 1. quantize a vector pair and run ONE mxdotp instruction ----
    println!("== one mxdotp instruction ==");
    let a = rng.normal_vec(8, 2.0);
    let b = rng.normal_vec(8, 2.0);
    let qa = MxVector::quantize(&a, ElemFormat::E4M3, 8);
    let qb = MxVector::quantize(&b, ElemFormat::E4M3, 8);
    let mut unit = MxDotpUnit::new(ElemFormat::E4M3);
    let acc =
        unit.execute_unpacked(&qa.elems[..8], &qb.elems[..8], qa.scales[0].0, qb.scales[0].0, 0.0);
    let exact: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    println!("  mxdotp  = {acc:.4}");
    println!("  exact   = {exact:.4}  (difference is MXFP8 quantization error)");

    // --- 2. a full MX matmul, reference semantics ---------------------
    println!("\n== 64x128x64 MX matmul (reference semantics) ==");
    let p = MmProblem { m: 64, k: 128, n: 64, fmt: ElemFormat::E4M3, block_size: 32 };
    let a = rng.normal_vec(p.m * p.k, 1.0);
    let b = rng.normal_vec(p.k * p.n, 1.0);
    let qa = MxMatrix::quantize(&a, p.m, p.k, p.fmt, 32, ScaleAxis::Row);
    let qb = MxMatrix::quantize(&b, p.k, p.n, p.fmt, 32, ScaleAxis::Col);
    let c_mx = dot::matmul_ref(&qa, &qb);
    let c_f32 = dot::matmul_f32(&a, &b, p.m, p.k, p.n);
    let rel = {
        let num: f64 = c_mx.iter().zip(&c_f32).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = c_f32.iter().map(|&y| (y as f64).powi(2)).sum();
        (num / den).sqrt()
    };
    println!("  relative error vs FP32: {:.3} % (MX is a drop-in replacement)", rel * 100.0);
    println!(
        "  memory: {} B quantized vs {} B FP32 ({:.1}x smaller)",
        qa.footprint_bytes() + qb.footprint_bytes(),
        4 * (a.len() + b.len()),
        4.0 * (a.len() + b.len()) as f64 / (qa.footprint_bytes() + qb.footprint_bytes()) as f64
    );

    // --- 3. the same matmul on the cycle-accurate 8-core cluster -----
    println!("\n== the same matmul on the simulated Snitch cluster ==");
    for kind in [KernelKind::Fp32, KernelKind::Fp8ToFp32, KernelKind::Mx(p.fmt)] {
        let run = run_mm(kind, p, &a, &b, 8);
        println!("  {}", render_run(&run));
    }
    println!("\nNext: `cargo run --release --example mm_kernels` for the full Fig. 4 sweep.");
}
