//! Fig. 4 end to end: run the three MM kernels of Fig. 2 on the
//! cycle-accurate 8-core cluster across the inner-dimension sweep and
//! print both subfigures plus the §IV-C headline comparison.
//!
//! ```sh
//! cargo run --release --example mm_kernels [e4m3|e5m2] [cores]
//! ```

use mxdotp::formats::ElemFormat;
use mxdotp::report::{fig4_sweep, render_fig3, render_fig4, render_table3, table3_cluster_point};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fmt = args
        .first()
        .and_then(|s| ElemFormat::parse(s))
        .unwrap_or(ElemFormat::E4M3);
    let cores: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    println!("running the Fig. 4 sweep ({fmt}, {cores} cores) on the cycle-accurate cluster...\n");
    let points = fig4_sweep(fmt, cores, 42);
    println!("{}", render_fig4(&points, fmt));

    println!("\n{}", render_fig3());

    let cluster = table3_cluster_point(42);
    println!("\n{}", render_table3(Some(&cluster)));
}
