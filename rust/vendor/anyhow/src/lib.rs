//! Minimal offline stand-in for the `anyhow` crate, providing the
//! subset of its API this workspace uses: [`Error`], [`Result`],
//! [`anyhow!`], [`bail!`] and the [`Context`] extension trait.
//!
//! Semantics match upstream where it matters:
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   so the blanket `From<E: std::error::Error>` conversion (what makes
//!   `?` work on concrete error types) does not overlap `From<Error>`;
//! * `.context(..)` wraps the underlying message rather than replacing
//!   it.
//!
//! The error chain is kept as a rendered string — downcasting and
//! backtraces are not supported, and nothing in this workspace uses
//! them.

use std::fmt;

/// A type-erased error: a rendered message plus optional source text.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, upstream-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints the Debug form on
        // error: keep it human-readable.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Render the chain of sources inline, like anyhow's {:#}.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` to `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_wraps_both_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening artifact").unwrap_err();
        assert!(e.to_string().starts_with("opening artifact: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        assert_eq!(anyhow!("bad value {x}").to_string(), "bad value 3");
        assert_eq!(anyhow!("bad value {}", 4).to_string(), "bad value 4");
        fn bails() -> Result<()> {
            bail!("stop {}", "here")
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop here");
    }
}
