//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libxla and exposes a PJRT CPU client; this
//! environment has neither network nor the native library, so this
//! stub keeps the `runtime` module compiling with the same type
//! surface while making unavailability a *runtime* condition:
//! [`PjRtClient::cpu`] returns an error, which `Runtime::new` already
//! propagates gracefully (the CLI prints "PJRT: unavailable", the
//! integration tests skip, and serving falls back to the
//! `ShardedExecutor`, which needs no XLA at all).
//!
//! Every other constructor is unreachable without a client, but all
//! methods are implemented (as errors) so the stub stays honest if
//! call order ever changes.

use std::fmt;

/// Error type matching the `{e:?}`-style uses in the runtime layer.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error("XLA/PJRT native runtime is not available in this offline build (stub crate)".into())
}

/// Marker for element types a [`Literal`] can expose.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host tensor (stub: shape + f32 data only).
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), shape: vec![v.len() as i64] }
    }

    /// Reshape without moving data (element count must match).
    pub fn reshape(&self, shape: &[i64]) -> Result<Literal, Error> {
        let want: i64 = shape.iter().product();
        if want != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {shape:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), shape: shape.to_vec() })
    }

    /// Destructure a tuple literal (stub literals are never tuples).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    /// Copy out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: never constructible).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; one result list per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// The PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// In the real crate this spins up the CPU PJRT plugin; the stub
    /// reports unavailability so callers degrade gracefully.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable_not_panicking() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("offline"));
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
