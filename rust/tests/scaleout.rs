//! Scale-out engine integration tests: the sharded MX GEMM (any
//! element format; MXFP8 in most tests) must be
//! **bit-identical** to the single-cluster kernel for any cluster
//! count — including non-divisible M/N/K shapes that exercise the
//! padding and MX-block edge cases — and must show real strong-scaling
//! speedup on the DeiT-Tiny workload.

use mxdotp::formats::ElemFormat;
use mxdotp::kernels::reference::mx_hw_ref;
use mxdotp::kernels::{run_mm, KernelKind, MmProblem};
use mxdotp::rng::XorShift;
use mxdotp::scaleout::{
    sharded_mm, sharded_mm_with_cache, PlanCache, ScaleoutConfig, SplitStrategy,
};
use mxdotp::workload::DeitConfig;

fn problem(m: usize, k: usize, n: usize) -> MmProblem {
    MmProblem { m, k, n, fmt: ElemFormat::E4M3, block_size: 32 }
}

fn inputs(p: &MmProblem, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = XorShift::new(seed);
    (rng.normal_vec(p.m * p.k, 1.0), rng.normal_vec(p.k * p.n, 0.5))
}

/// The oracle for arbitrary shapes: zero-pad K to a block multiple
/// (bit-neutral, see `scaleout::partition`) and evaluate the
/// element-wise single-`mxdotp`-chain reference.
fn oracle(p: &MmProblem, a: &[f32], b: &[f32]) -> Vec<f32> {
    let k_pad = p.k.div_ceil(p.block_size) * p.block_size;
    let pp = MmProblem { k: k_pad, ..*p };
    let mut a_pad = vec![0.0f32; p.m * k_pad];
    for m in 0..p.m {
        a_pad[m * k_pad..m * k_pad + p.k].copy_from_slice(&a[m * p.k..(m + 1) * p.k]);
    }
    let mut b_pad = vec![0.0f32; k_pad * p.n];
    b_pad[..p.k * p.n].copy_from_slice(b);
    mx_hw_ref(&pp, &a_pad, &b_pad)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: C[{i}] = {g:?} ({:#010x}) vs {w:?} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

#[test]
fn sharded_gemm_bit_identical_across_cluster_counts_divisible_shape() {
    let p = problem(32, 64, 16);
    let (a, b) = inputs(&p, 0xA11CE);
    let want = sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b);
    // ... and the single-cluster result equals the plain kernel path
    let direct = run_mm(KernelKind::Mx(p.fmt), p, &a, &b, 8);
    assert_bits_eq(&want.c, &direct.c, "1 cluster vs direct run_mm");
    for clusters in [2usize, 4, 8] {
        let got = sharded_mm(&ScaleoutConfig::with_clusters(clusters), p, &a, &b);
        assert_bits_eq(&got.c, &want.c, &format!("{clusters} clusters"));
    }
}

#[test]
fn sharded_gemm_bit_identical_on_non_divisible_shapes() {
    // M not a multiple of the 8-core row granule, N not a multiple of
    // the 8-column tile, K not a multiple of the 32-element MX block:
    // every padding path at once, plus single-row/column extremes.
    for (m, k, n) in [(13usize, 40usize, 10usize), (21, 96, 17), (5, 32, 8), (1, 33, 1)] {
        let p = problem(m, k, n);
        let (a, b) = inputs(&p, (m * 1000 + k * 10 + n) as u64);
        let want = oracle(&p, &a, &b);
        for clusters in [1usize, 2, 8] {
            let got = sharded_mm(&ScaleoutConfig::with_clusters(clusters), p, &a, &b);
            assert_bits_eq(
                &got.c,
                &want,
                &format!("{m}x{k}x{n} on {clusters} clusters vs oracle"),
            );
        }
    }
}

#[test]
fn sharded_gemm_bit_identical_for_every_element_format() {
    // The format-generic datapath threaded through the scale-out stack:
    // for every OCP element format — including nibble-packed FP4 (16
    // lanes/issue) and MXINT8 — the sharded result must equal the
    // oracle on a non-divisible shape for any cluster count.
    for fmt in ElemFormat::ALL {
        let p = MmProblem { m: 13, k: 40, n: 10, fmt, block_size: 32 };
        let (a, b) = inputs(&p, 0xF0F ^ fmt.csr_code() as u64);
        let want = oracle(&p, &a, &b);
        for clusters in [1usize, 2] {
            let got = sharded_mm(&ScaleoutConfig::with_clusters(clusters), p, &a, &b);
            assert_bits_eq(&got.c, &want, &format!("{fmt} on {clusters} clusters"));
        }
    }
}

#[test]
fn k_split_reduction_is_deterministic_and_exact_on_integer_data() {
    // With small-integer operands every product and partial sum is
    // exactly representable, so no accumulation step rounds and the
    // K-chunked reduction must agree bit-for-bit with the fused chain.
    let p = problem(16, 128, 8);
    let mut rng = XorShift::new(0x1437);
    let a: Vec<f32> = (0..p.m * p.k).map(|_| rng.range_i64(-3, 3) as f32).collect();
    let b: Vec<f32> = (0..p.k * p.n).map(|_| rng.range_i64(-2, 2) as f32).collect();
    let fused = sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b);
    for clusters in [2usize, 4] {
        let cfg = ScaleoutConfig {
            clusters,
            strategy: SplitStrategy::MkSplit { k_chunks: 2 },
            ..ScaleoutConfig::default()
        };
        let got = sharded_mm(&cfg, p, &a, &b);
        assert_eq!(got.shards, clusters.div_ceil(2) * 2);
        assert_bits_eq(&got.c, &fused.c, &format!("MkSplit on {clusters} clusters"));
    }
}

#[test]
fn k_split_on_real_data_is_close_and_cluster_count_invariant() {
    let p = problem(16, 128, 8);
    let (a, b) = inputs(&p, 0xBEEF);
    let fused = sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b);
    let mk = |clusters| ScaleoutConfig {
        clusters,
        strategy: SplitStrategy::MkSplit { k_chunks: 2 },
        ..ScaleoutConfig::default()
    };
    let two = sharded_mm(&mk(2), p, &a, &b);
    let four = sharded_mm(&mk(4), p, &a, &b);
    // chunk combine order is fixed, so the result does not depend on
    // how many clusters executed the chunks
    assert_bits_eq(&four.c, &two.c, "MkSplit 4 vs 2 clusters");
    // and differs from the fused chain only by final-reduction rounding
    for (i, (t, f)) in two.c.iter().zip(&fused.c).enumerate() {
        let d = (t - f).abs();
        assert!(d <= 1e-4 * f.abs().max(1.0), "C[{i}]: {t} vs {f}");
    }
}

#[test]
fn warm_plans_are_bit_identical_and_strictly_faster_on_repeated_deit_gemm() {
    // The plan-cache acceptance test: a repeated DeiT-shaped GEMM must
    // (a) return bit-identical C and identical simulated counters, and
    // (b) take strictly less host wall-clock, because the second run
    // reuses the compiled plans, the quantized B tiles and the
    // memoized passes instead of re-simulating.
    let cfg = DeitConfig { seq: 64, ..DeitConfig::default() };
    let p = cfg.mx_matmuls()[1]; // attention-out projection 64x192x192
    let (a, b) = inputs(&p, 0x3A3A);
    let cache = PlanCache::new();
    let scfg = ScaleoutConfig::with_clusters(2);

    let t0 = std::time::Instant::now();
    let cold = sharded_mm_with_cache(&scfg, p, &a, &b, &cache);
    let cold_s = t0.elapsed();
    let t1 = std::time::Instant::now();
    let warm = sharded_mm_with_cache(&scfg, p, &a, &b, &cache);
    let warm_s = t1.elapsed();

    assert_bits_eq(&warm.c, &cold.c, "warm vs cold plans");
    assert_eq!(warm.wall_cycles, cold.wall_cycles, "cycle model must not change");
    assert_eq!(warm.total_cycles, cold.total_cycles);
    assert_eq!(warm.total_mxdotp, cold.total_mxdotp);
    assert!(
        (warm.total_energy_uj - cold.total_energy_uj).abs() < 1e-9,
        "energy model must not change"
    );
    let st = cache.stats();
    assert!(st.pass_hits > 0, "second run must hit the pass cache: {st:?}");
    assert_eq!(
        st.pass_hits, st.pass_misses,
        "every cold pass must be served from cache on the warm run: {st:?}"
    );
    assert!(
        warm_s < cold_s,
        "warm plans not faster: warm {warm_s:?} vs cold {cold_s:?}"
    );
}

#[test]
fn cold_plans_escape_hatch_matches_warm_path_bitwise() {
    // --cold-plans must change host wall-clock only, never results or
    // the simulated cycle/energy model.
    let p = problem(16, 96, 24);
    let (a, b) = inputs(&p, 0xC0DE);
    let warm = sharded_mm(&ScaleoutConfig::with_clusters(2), p, &a, &b);
    let cold = sharded_mm(
        &ScaleoutConfig { cold_plans: true, ..ScaleoutConfig::with_clusters(2) },
        p,
        &a,
        &b,
    );
    assert_bits_eq(&cold.c, &warm.c, "cold-plans vs warm");
    assert_eq!(cold.wall_cycles, warm.wall_cycles);
    assert_eq!(cold.total_cycles, warm.total_cycles);
}

#[test]
fn deit_workload_reaches_4x_throughput_on_8_clusters() {
    // The acceptance bar: ≥ 4x simulated-cycle throughput at N=8 under
    // the wall-clock = max-over-clusters model, on DeiT-Tiny-shaped
    // matmuls (shortened sequence keeps the cycle-accurate sweep fast;
    // dim/heads/MLP shapes are DeiT-Tiny's).
    let cfg = DeitConfig { seq: 64, ..DeitConfig::default() };
    // attention-out projection: seq × dim × dim
    let p = cfg.mx_matmuls()[1];
    let (a, b) = inputs(&p, 0xDE17);
    let one = sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b);
    let eight = sharded_mm(&ScaleoutConfig::with_clusters(8), p, &a, &b);
    assert_bits_eq(&eight.c, &one.c, "DeiT proj on 8 clusters");
    let speedup = eight.speedup_vs(&one);
    assert!(
        speedup >= 4.0,
        "8-cluster speedup {speedup:.2}x below the 4x acceptance bar \
         (wall {} vs {})",
        eight.wall_cycles,
        one.wall_cycles
    );
    // all eight clusters participated
    assert_eq!(eight.clusters.iter().filter(|s| s.cycles > 0).count(), 8);
    // fabric energy stays within a factor of the serial energy (same
    // dynamic work, idle floor integrated over busy cycles only)
    assert!(eight.total_energy_uj > 0.5 * one.total_energy_uj);
    assert!(eight.total_energy_uj < 2.0 * one.total_energy_uj);
}
