//! Fleet-layer property suite (DESIGN.md §17): the correctness
//! contract of the deterministic global router, fair-share admission,
//! and hysteresis autoscaler, checked through the public API only.
//!
//! Every property here is what the fleet layer *promises*, not what it
//! happens to do: bit-identical replay of the same trace, exact
//! request conservation, strictly cheaper reloads under the affinity
//! router, no tenant starvation under adversarial overload, no
//! autoscaler thrash inside a cooldown window, and `--machines 1`
//! collapsing to the unmodified PR 4 engine. The timing engine is
//! analytic, so everything except the cycle-audited spot-check test
//! runs in host milliseconds.

use mxdotp::fleet::{
    simulate_fleet, spot_check_fleet, AutoscaleConfig, FairShareConfig, FleetConfig,
    FleetRejectReason, RouterKind,
};
use mxdotp::formats::ElemFormat;
use mxdotp::obs;
use mxdotp::report::{fleet_machine, fleet_trace};
use mxdotp::serve::{self, estimated_capacity_per_ktick, CostModel, ServeConfig};
use mxdotp::workload::arrivals::{
    assign_tenants, generate_trace, Arrival, ArrivalSpec, TenantSpec,
};
use mxdotp::workload::DeitConfig;

/// A deliberately small machine (seq-64 model) so analytic fleet runs
/// stay cheap in the debug test profile.
fn small_machine() -> ServeConfig {
    ServeConfig {
        model: DeitConfig { seq: 64, ..DeitConfig::default() },
        clusters: 4,
        fabrics: 2,
        ..ServeConfig::default()
    }
}

#[test]
fn same_trace_replay_is_bit_identical_down_to_the_artifacts() {
    // The determinism property CI leans on when it byte-compares
    // BENCH_fleet.json: the outcome — and every artifact rendered
    // from it — is a pure function of (config, trace, tenants), even
    // with both optional fleet policies engaged.
    let machine = small_machine();
    let cap = 3.0 * estimated_capacity_per_ktick(&machine, &[(ElemFormat::E4M3, 1.0)]);
    let cfg = FleetConfig {
        fairshare: Some(FairShareConfig {
            weights: vec![2.0, 1.0],
            admit_rate_per_ktick: cap * 0.9,
            burst: 8.0,
            saturation_ticks: 2000,
        }),
        autoscale: Some(AutoscaleConfig {
            min_machines: 1,
            max_machines: 3,
            epoch_ticks: 2000,
            hi_util: 0.8,
            lo_util: 0.2,
            cooldown_ticks: 4000,
        }),
        ..FleetConfig::new(machine, 3, RouterKind::Affinity)
    };
    let trace = fleet_trace(&machine, 3, 300, 42);
    let tenants = assign_tenants(&trace, &TenantSpec { weights: vec![3.0, 1.0], seed: 7 });
    let a = simulate_fleet(&cfg, &trace, &tenants);
    let b = simulate_fleet(&cfg, &trace, &tenants);
    assert_eq!(a, b, "same (cfg, trace, tenants) must reproduce the outcome bit-for-bit");
    assert_eq!(
        obs::fleet_metrics(&a).render_json(),
        obs::fleet_metrics(&b).render_json(),
        "rendered metrics must byte-compare"
    );
    assert_eq!(
        obs::perfetto::render(&obs::fleet_spans(&a)),
        obs::perfetto::render(&obs::fleet_spans(&b)),
        "rendered span traces must byte-compare"
    );
}

#[test]
fn every_arrival_is_served_or_typed_rejected_exactly_once() {
    // Conservation under the worst case: overload plus a fair-share
    // gate, so all three disposal paths (served, machine-rejected,
    // fleet-rejected) are exercised and still partition the id space.
    let machine = small_machine();
    let rate = 3.0 * estimated_capacity_per_ktick(&machine, &[(ElemFormat::E4M3, 1.0)]);
    let cfg = FleetConfig {
        fairshare: Some(FairShareConfig {
            weights: vec![1.0, 1.0],
            admit_rate_per_ktick: rate / 2.0,
            burst: 4.0,
            saturation_ticks: 1000,
        }),
        ..FleetConfig::new(machine, 2, RouterKind::Affinity)
    };
    let trace: Vec<Arrival> =
        generate_trace(&ArrivalSpec::poisson(rate, ElemFormat::E4M3, 500, 17));
    let tenants = assign_tenants(&trace, &TenantSpec { weights: vec![1.0, 1.0], seed: 3 });
    let out = simulate_fleet(&cfg, &trace, &tenants);
    assert_eq!(out.offered(), 500);
    let mut ids: Vec<u64> = out
        .machines
        .iter()
        .flat_map(|m| m.outcome.served.iter().map(|r| r.id))
        .chain(out.machines.iter().flat_map(|m| m.outcome.rejected.iter().map(|r| r.id)))
        .chain(out.fleet_rejected.iter().map(|r| r.id))
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..500).collect::<Vec<u64>>(), "ids must partition exactly once");
    // typed, never silent
    assert!(out.fleet_rejected.iter().all(|r| r.reason == FleetRejectReason::FairShare));
    // and the per-tenant ledger balances against the same totals
    for t in &out.per_tenant {
        assert_eq!(t.offered, t.served + t.machine_rejected + t.fleet_rejected);
    }
}

#[test]
fn affinity_routing_pays_strictly_fewer_reload_ticks_than_round_robin() {
    // On the canonical mixed-policy trace (four equal policy classes)
    // over four single-fabric machines, policy-blind round-robin must
    // pay strictly more weight-reload ticks — and no more goodput —
    // than the affinity router. This is the mechanism behind the
    // BENCH_fleet 1.15x goodput bar, pinned at test scale.
    let machine = ServeConfig {
        clusters: 4,
        ..fleet_machine(DeitConfig { seq: 64, ..DeitConfig::default() })
    };
    let trace = fleet_trace(&machine, 4, 400, 42);
    let costs = CostModel::build(&machine);
    let run = |router| simulate_fleet(&FleetConfig::new(machine, 4, router), &trace, &[]);
    let aff = run(RouterKind::Affinity);
    let rr = run(RouterKind::RoundRobin);
    let (at, rt) = (aff.reload_ticks(&costs), rr.reload_ticks(&costs));
    assert!(at < rt, "affinity paid {at} reload ticks vs round-robin {rt}");
    assert!(
        aff.goodput_per_ktick() >= rr.goodput_per_ktick(),
        "affinity goodput {:.3} fell below round-robin {:.3}",
        aff.goodput_per_ktick(),
        rr.goodput_per_ktick()
    );
}

#[test]
fn fair_share_never_starves_the_entitled_tenant_under_adversarial_overload() {
    // Tenant 0 floods 9x tenant 1's traffic into a fleet offered 3x
    // its capacity. With equal fair-share weights, tenant 1 stays
    // within its entitlement, so the gate must keep admitting it at
    // full rate while the flooder absorbs the fleet rejects.
    let machine = small_machine();
    let cap = 2.0 * estimated_capacity_per_ktick(&machine, &[(ElemFormat::E4M3, 1.0)]);
    let cfg = FleetConfig {
        fairshare: Some(FairShareConfig {
            weights: vec![1.0, 1.0],
            admit_rate_per_ktick: cap * 0.9,
            burst: 4.0,
            saturation_ticks: 1500,
        }),
        ..FleetConfig::new(machine, 2, RouterKind::Affinity)
    };
    let trace = generate_trace(&ArrivalSpec::poisson(3.0 * cap, ElemFormat::E4M3, 600, 23));
    let tenants = assign_tenants(&trace, &TenantSpec { weights: vec![9.0, 1.0], seed: 31 });
    let out = simulate_fleet(&cfg, &trace, &tenants);
    let flooder = &out.per_tenant[0];
    let entitled = &out.per_tenant[1];
    assert!(
        !out.fleet_rejected.is_empty(),
        "3x overload must saturate the gate or the test proves nothing"
    );
    // the entitled tenant is (almost) never turned away at the fleet
    // boundary: its offered rate sits below its weighted share
    assert!(
        entitled.fleet_rejected * 10 <= entitled.offered,
        "entitled tenant lost {}/{} to fair-share",
        entitled.fleet_rejected,
        entitled.offered
    );
    // and it actually gets work done — no starvation via queues either
    assert!(
        entitled.served * 2 >= entitled.offered,
        "entitled tenant served only {}/{}",
        entitled.served,
        entitled.offered
    );
    assert!(entitled.served_in_slo > 0);
    // the flooder pays: it takes the overwhelming share of rejects
    assert!(
        flooder.fleet_rejected > entitled.fleet_rejected,
        "flooder {} vs entitled {} fleet rejects",
        flooder.fleet_rejected,
        entitled.fleet_rejected
    );
}

#[test]
fn autoscaler_is_deterministic_and_never_thrashes_within_cooldown() {
    let machine = small_machine();
    let rate = 2.5 * estimated_capacity_per_ktick(&machine, &[(ElemFormat::E4M3, 1.0)]);
    let cfg = FleetConfig {
        autoscale: Some(AutoscaleConfig {
            min_machines: 1,
            max_machines: 3,
            epoch_ticks: 1000,
            hi_util: 0.8,
            lo_util: 0.2,
            cooldown_ticks: 2500,
        }),
        ..FleetConfig::new(machine, 3, RouterKind::Affinity)
    };
    let trace = generate_trace(&ArrivalSpec::poisson(rate, ElemFormat::E4M3, 600, 5));
    let a = simulate_fleet(&cfg, &trace, &[]);
    let b = simulate_fleet(&cfg, &trace, &[]);
    assert_eq!(a.scale_events, b.scale_events, "scale events must be bit-deterministic");
    assert!(
        !a.scale_events.is_empty(),
        "sustained 2.5x overload from a 1-machine lease must scale up"
    );
    for w in a.scale_events.windows(2) {
        assert!(
            w[1].tick - w[0].tick >= 2500,
            "thrash: scale events at ticks {} and {} inside the cooldown",
            w[0].tick,
            w[1].tick
        );
        // single-step moves only, and each event is a real change
        assert_eq!(w[0].to.abs_diff(w[0].from), 1);
    }
    let peak = a.scale_events.iter().map(|e| e.to.max(e.from)).max().unwrap();
    assert_eq!(a.peak_machines, peak, "peak lease must match the event log");
    assert!(a.peak_machines <= 3);
}

#[test]
fn single_machine_fleet_is_tick_identical_to_the_pr4_engine() {
    // `mxdotp-cli serve --machines 1` must not change a single tick
    // relative to the PR 4 engine, whichever router is configured —
    // the fleet layer is a strict superset, not a reinterpretation.
    let machine = small_machine();
    let trace = fleet_trace(&machine, 1, 250, 13);
    let single = serve::simulate(&machine, &trace);
    for router in [RouterKind::Affinity, RouterKind::RoundRobin] {
        let fleet = simulate_fleet(&FleetConfig::new(machine, 1, router), &trace, &[]);
        assert_eq!(fleet.machines.len(), 1);
        assert_eq!(fleet.machines[0].routed, 250);
        assert_eq!(
            fleet.machines[0].outcome, single,
            "router {router} altered the single-machine outcome"
        );
        assert_eq!(fleet.horizon_ticks, single.horizon_ticks);
    }
}

#[test]
fn fleet_spot_check_flags_seeded_calibration_drift() {
    // The sampled-exec audit must actually bite: corrupt the machine's
    // calibration (util far below reality) and the fleet spot-check
    // has to report out-of-tolerance — this is what `--exec sampled:N`
    // turns into a non-zero exit. Tiny model: the audit replays the
    // sample on the cycle engine.
    let machine = ServeConfig {
        model: DeitConfig { seq: 16, ..DeitConfig::default() },
        clusters: 2,
        fabrics: 2,
        ..ServeConfig::default()
    };
    let trace = generate_trace(&ArrivalSpec::poisson(4.0, ElemFormat::E4M3, 30, 13));
    let drifted = ServeConfig { util: 0.05, ..machine };
    let cfg = FleetConfig::new(drifted, 2, RouterKind::RoundRobin);
    let out = simulate_fleet(&cfg, &trace, &[]);
    let rep = spot_check_fleet(&cfg, &out, 8, 42);
    assert!(!rep.checks.is_empty());
    assert!(
        !rep.within_tolerance(),
        "a 15x calibration error must trip the divergence gate (max_rel_err {})",
        rep.max_rel_err
    );
}
