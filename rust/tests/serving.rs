//! End-to-end serving-engine tests (DESIGN.md §12): the acceptance
//! invariants of the admission-controlled continuous batcher, checked
//! through the public API only.
//!
//! The timing engine is analytic (calibrated cost model, no
//! cycle-accurate simulation in the loop), so these run in host
//! milliseconds; the bit-identity test executes real forward passes on
//! a reduced DeiT-shaped model.

use mxdotp::formats::ElemFormat;
use mxdotp::report::{serving_headline_ratio, serving_sweep, SERVING_LOAD_MULTS};
use mxdotp::serve::{
    estimated_capacity_per_ktick, simulate, verify_schedulers_bit_identical, SchedulerKind,
    ServeConfig,
};
use mxdotp::workload::arrivals::{generate_trace, ArrivalKind, ArrivalSpec};
use mxdotp::workload::DeitConfig;

fn mixed() -> Vec<(ElemFormat, f64)> {
    vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)]
}

#[test]
fn p99_under_slo_sized_load_stays_below_the_slo_on_the_default_fabric() {
    // The satellite acceptance property: at an SLO-sized load (half
    // the machine's capacity) on the default fabric configuration,
    // the served p99 stays below --slo-ticks.
    let cfg = ServeConfig::default(); // 8 clusters, one fabric each
    let rate = 0.5 * estimated_capacity_per_ktick(&cfg, &mixed());
    let spec = ArrivalSpec {
        kind: ArrivalKind::Poisson,
        rate_per_ktick: rate,
        mix: mixed(),
        high_priority_frac: 0.1,
        requests: 300,
        seed: 1,
    };
    let out = simulate(&cfg, &generate_trace(&spec));
    assert!(
        out.served.len() >= 295,
        "half-capacity load shed {} requests",
        300 - out.served.len()
    );
    let p = out.percentiles();
    assert!(
        p.p99 < out.slo_ticks,
        "p99 {} ticks must stay below the SLO {} ticks",
        p.p99,
        out.slo_ticks
    );
    assert!(
        out.served_in_slo() + 3 >= out.served.len(),
        "{}/{} in SLO",
        out.served_in_slo(),
        out.served.len()
    );
}

#[test]
fn goodput_bar_on_the_8_cluster_machine() {
    // The tentpole acceptance criterion at full DeiT-Tiny scale: over
    // identical traces on an 8-cluster machine, the continuous
    // batcher's goodput at the highest offered load is >= 1.5x the
    // seed barrier batcher's.
    let cfg = ServeConfig { clusters: 8, ..ServeConfig::default() };
    assert_eq!(cfg.model.seq, 256, "full DeiT-Tiny sequence");
    let pts = serving_sweep(&cfg, &mixed(), 400, 42, &SERVING_LOAD_MULTS);
    assert_eq!(pts.len(), SERVING_LOAD_MULTS.len() * 2);
    let ratio = serving_headline_ratio(&pts).unwrap();
    assert!(ratio >= 1.5, "continuous/barrier goodput at top load only {ratio:.2}x");
    // and the collapse is the barrier's, not an artifact: the barrier
    // still moves requests (throughput) while its goodput dies.
    let top = *SERVING_LOAD_MULTS.last().unwrap();
    let barrier_top =
        pts.iter().find(|p| p.load_mult == top && p.sched == SchedulerKind::Barrier).unwrap();
    assert!(barrier_top.throughput_per_ktick > 0.0);
    assert!(
        barrier_top.goodput_per_ktick < barrier_top.throughput_per_ktick / 2.0,
        "expected congestion collapse: goodput {} vs throughput {}",
        barrier_top.goodput_per_ktick,
        barrier_top.throughput_per_ktick
    );
}

#[test]
fn schedulers_produce_bit_identical_request_results() {
    // Real executors, reduced model: every request served by both
    // schedulers must produce bit-identical output even though the
    // schedulers batch and order the work differently.
    let model = DeitConfig { seq: 8, ..DeitConfig::default() };
    let compared = verify_schedulers_bit_identical(&model, &mixed(), 10, 3);
    assert!(compared >= 5, "only {compared} requests overlapped between schedulers");
}

#[test]
fn bursty_traffic_is_fully_accounted_and_format_queues_absorb_bursts() {
    let cfg = ServeConfig::default();
    let rate = estimated_capacity_per_ktick(&cfg, &mixed());
    let spec = ArrivalSpec {
        kind: ArrivalKind::Bursty { burst_factor: 8.0, period_ticks: 4000 },
        rate_per_ktick: rate, // mean at capacity, bursts at 8x
        mix: mixed(),
        high_priority_frac: 0.0,
        requests: 250,
        seed: 9,
    };
    let trace = generate_trace(&spec);
    for sched in [SchedulerKind::Barrier, SchedulerKind::Continuous] {
        let out = simulate(&ServeConfig { scheduler: sched, ..cfg }, &trace);
        assert_eq!(out.offered(), 250, "{sched}: lost requests under bursts");
        assert!(out.batches > 0);
    }
    // the continuous engine keeps its admitted tail inside the SLO
    // even under 8x bursts (admission sheds the excess with reasons)
    let out = simulate(&cfg, &trace);
    let p = out.percentiles();
    assert!(
        p.p99 <= 2 * out.slo_ticks,
        "burst tail {} vs slo {}",
        p.p99,
        out.slo_ticks
    );
    assert!(
        out.served_in_slo() * 10 >= out.served.len() * 6,
        "bursts defeated admission control: {}/{} in SLO",
        out.served_in_slo(),
        out.served.len()
    );
}

#[test]
fn fabric_partitioning_shows_up_in_attribution() {
    // Continuous scheduling on 4 fabrics must actually use them and
    // stamp fabric ids into the attribution.
    let cfg = ServeConfig { clusters: 8, fabrics: 4, ..ServeConfig::default() };
    let rate = estimated_capacity_per_ktick(&cfg, &mixed());
    let spec = ArrivalSpec {
        kind: ArrivalKind::Poisson,
        rate_per_ktick: rate,
        mix: mixed(),
        high_priority_frac: 0.0,
        requests: 200,
        seed: 4,
    };
    let out = simulate(&cfg, &generate_trace(&spec));
    assert_eq!(out.fabric_busy_ticks.len(), 4);
    let mut used: Vec<usize> = out.served.iter().map(|r| r.fabric).collect();
    used.sort_unstable();
    used.dedup();
    assert_eq!(used, vec![0, 1, 2, 3], "all four fabrics must serve work at capacity load");
    // per-format service ticks differ by lane width in the attribution
    let svc_of = |fmt| {
        out.served.iter().find(|r| r.fmt == fmt).map(|r| r.service_ticks).unwrap()
    };
    let (f8, f4) = (svc_of(ElemFormat::E4M3), svc_of(ElemFormat::E2M1));
    assert!(
        (f8 as f64 / f4 as f64 - 2.0).abs() < 0.05,
        "MXFP4 requests must cost half the ticks: {f8} vs {f4}"
    );
}
