//! Integration tests for the simulator fast path (DESIGN.md §15):
//! pre-decoded cores + FREP fast-forwarding must be bit- and
//! counter-invisible, and layer-run cache hits must replay runs
//! bit-identical to cold simulation.

use mxdotp::formats::ElemFormat;
use mxdotp::kernels::plan::{run_mm_cached, PlanCache};
use mxdotp::kernels::{KernelKind, MmProblem, MmRun};
use mxdotp::model::{policy_hw_run, ModelGraph, PrecisionPolicy};
use mxdotp::rng::property_cases;
use mxdotp::scaleout::{sharded_mm_with_cache, ScaleoutConfig, ShardedRun};
use mxdotp::snitch::{Cluster, ClusterConfig};
use mxdotp::workload::DeitConfig;

/// Run one kernel on a fresh cluster with the fast path forced on or
/// off for that instance (the per-instance flag, not the process-wide
/// default — tests in this binary run concurrently).
fn run_with(fast: bool, kind: KernelKind, p: MmProblem, a: &[f32], b: &[f32]) -> MmRun {
    let cache = PlanCache::disabled();
    let mut cl = Cluster::new(ClusterConfig { num_cores: 8, freq_ghz: 1.0 });
    cl.fast_path = fast;
    run_mm_cached(&cache, &mut cl, kind, p, a, b)
}

/// Full bit/counter comparison of a fast-path and a slow-path run.
fn assert_runs_identical(slow: &MmRun, fast: &MmRun, what: &str) {
    // PerfCounters equality covers cycles, stalls, per-core integer
    // counters and per-core FPU counters (issue counts, accumulator
    // traffic) — the fast path may not perturb any of them.
    assert_eq!(slow.perf, fast.perf, "{what}: fast path changed the counters");
    assert_eq!(slow.c.len(), fast.c.len(), "{what}: result shape changed");
    for (i, (s, f)) in slow.c.iter().zip(&fast.c).enumerate() {
        assert_eq!(
            s.to_bits(),
            f.to_bits(),
            "{what}: fast path changed C[{i}] ({s} vs {f})"
        );
    }
}

#[test]
fn fast_path_is_bit_and_counter_invisible_across_formats_and_shapes() {
    // All six OCP element formats × random (block-aligned) shapes:
    // the FREP fast-forward and the pre-decoded scalar fast cycle must
    // retire exactly what per-cycle stepping retires.
    property_cases(12, 0xFA57_A711, |rng| {
        let fmt = ElemFormat::ALL[rng.below(ElemFormat::ALL.len() as u64) as usize];
        let p = MmProblem {
            m: 8 * (1 + rng.below(3) as usize),
            k: 32 * (1 + rng.below(3) as usize),
            n: 8 * (1 + rng.below(3) as usize),
            fmt,
            block_size: 32,
        };
        let a = rng.normal_vec(p.m * p.k, 0.5);
        let b = rng.normal_vec(p.k * p.n, 0.02);
        let slow = run_with(false, KernelKind::Mx(fmt), p, &a, &b);
        let fast = run_with(true, KernelKind::Mx(fmt), p, &a, &b);
        assert_runs_identical(&slow, &fast, &format!("mx {fmt} {}x{}x{}", p.m, p.k, p.n));
    });
}

#[test]
fn fast_path_is_invisible_for_baseline_kernels() {
    // The FP32 and FP8-to-FP32 software kernels exercise the scalar
    // fast cycle (no MXDOTP FREP bodies) — different freeze/hazard
    // structure than the MX kernel.
    let p = MmProblem { m: 16, k: 64, n: 16, fmt: ElemFormat::E4M3, block_size: 32 };
    let mut rng = mxdotp::rng::XorShift::new(0xBA5E);
    let a = rng.normal_vec(p.m * p.k, 0.5);
    let b = rng.normal_vec(p.k * p.n, 0.02);
    for kind in [KernelKind::Fp32, KernelKind::Fp8ToFp32, KernelKind::Mx(ElemFormat::E4M3)] {
        let slow = run_with(false, kind, p, &a, &b);
        let fast = run_with(true, kind, p, &a, &b);
        assert_runs_identical(&slow, &fast, &format!("{kind:?}"));
    }
}

/// Field-by-field bit comparison of two sharded runs (ShardedRun does
/// not expose PartialEq; energies compare by f64 bits).
fn assert_sharded_identical(a: &ShardedRun, b: &ShardedRun, what: &str) {
    assert_eq!(a.wall_cycles, b.wall_cycles, "{what}: wall cycles differ");
    assert_eq!(a.total_cycles, b.total_cycles, "{what}: total cycles differ");
    assert_eq!(a.shards, b.shards, "{what}: shard counts differ");
    assert_eq!(
        a.total_energy_uj.to_bits(),
        b.total_energy_uj.to_bits(),
        "{what}: energy differs"
    );
    assert_eq!(a.c.len(), b.c.len(), "{what}: result shape differs");
    for (i, (x, y)) in a.c.iter().zip(&b.c).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: C[{i}] differs ({x} vs {y})");
    }
    assert_eq!(a.clusters.len(), b.clusters.len(), "{what}: cluster stats differ");
    for (x, y) in a.clusters.iter().zip(&b.clusters) {
        assert_eq!(
            (x.id, x.shards, x.passes, x.cycles, x.mxdotp, x.energy_uj.to_bits()),
            (y.id, y.shards, y.passes, y.cycles, y.mxdotp, y.energy_uj.to_bits()),
            "{what}: per-cluster stats differ"
        );
    }
}

#[test]
fn layer_run_cache_hits_are_bit_identical_to_cold_runs() {
    let scfg = ScaleoutConfig::with_clusters(2);
    property_cases(4, 0x1A9E_2C, |rng| {
        let fmt = ElemFormat::ALL[rng.below(ElemFormat::ALL.len() as u64) as usize];
        let p = MmProblem {
            m: 16 * (1 + rng.below(2) as usize),
            k: 32 * (1 + rng.below(3) as usize),
            n: 16,
            fmt,
            block_size: 32,
        };
        let a = rng.normal_vec(p.m * p.k, 0.5);
        let b = rng.normal_vec(p.k * p.n, 0.02);
        // cold reference: a cache that never stores (the --cold-plans
        // semantics) simulates every call and never hits layer runs
        let cold_cache = PlanCache::disabled();
        let cold = sharded_mm_with_cache(&scfg, p, &a, &b, &cold_cache);
        let again = sharded_mm_with_cache(&scfg, p, &a, &b, &cold_cache);
        assert_eq!(cold_cache.stats().layer_run_hits, 0, "disabled cache must never hit");
        assert_sharded_identical(&cold, &again, "cold repeat");
        // warm cache: first call misses and stores, second replays the
        // whole layer run from the cache
        let cache = PlanCache::new();
        let warm1 = sharded_mm_with_cache(&scfg, p, &a, &b, &cache);
        let warm2 = sharded_mm_with_cache(&scfg, p, &a, &b, &cache);
        let st = cache.stats();
        assert_eq!(st.layer_run_misses, 1, "{fmt}: first warm call must miss");
        assert_eq!(st.layer_run_hits, 1, "{fmt}: second warm call must replay");
        assert_sharded_identical(&cold, &warm1, "cold vs warm miss");
        assert_sharded_identical(&cold, &warm2, "cold vs layer-run replay");
    });
}

#[test]
fn layer_run_cache_keys_on_operand_fingerprints() {
    // Same shape, different operands: the fingerprint in the key must
    // force a fresh simulation (a stale hit here would be silent data
    // corruption, not a perf bug).
    let scfg = ScaleoutConfig::with_clusters(2);
    let p = MmProblem { m: 16, k: 64, n: 16, fmt: ElemFormat::E4M3, block_size: 32 };
    let mut rng = mxdotp::rng::XorShift::new(0xF1F0);
    let a1 = rng.normal_vec(p.m * p.k, 0.5);
    let b1 = rng.normal_vec(p.k * p.n, 0.02);
    let mut a2 = a1.clone();
    a2[0] += 1.0;
    let cache = PlanCache::new();
    let r1 = sharded_mm_with_cache(&scfg, p, &a1, &b1, &cache);
    let r2 = sharded_mm_with_cache(&scfg, p, &a2, &b1, &cache);
    assert_eq!(cache.stats().layer_run_hits, 0, "different operands must not hit");
    assert_eq!(cache.stats().layer_run_misses, 2);
    assert_ne!(
        r1.c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        r2.c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "perturbed operands must change the result"
    );
}

#[test]
fn policy_walks_replay_bit_identical_for_mixed_policies() {
    // The serving-path consumer of the layer-run cache: repeated
    // policy walks (model::policy_hw_run goes through sharded_mm and
    // the process-global cache) must be bit-identical to a cold walk,
    // for mixed per-layer policies too.
    let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
    let graph = ModelGraph::deit_block(&cfg);
    for name in ["fp4-ffn", "all-fp8"] {
        let policy = PrecisionPolicy::preset(name).unwrap();
        let cold = policy_hw_run(&graph, &policy, 2, 4, 7, true, 1);
        let warm1 = policy_hw_run(&graph, &policy, 2, 4, 7, false, 1);
        let warm2 = policy_hw_run(&graph, &policy, 2, 4, 7, false, 1);
        for run in [&warm1, &warm2] {
            assert_eq!(cold.wall_cycles, run.wall_cycles, "{name}: wall cycles differ");
            assert_eq!(cold.flops, run.flops, "{name}: flops differ");
            assert_eq!(cold.csr_switches, run.csr_switches, "{name}: CSR switches differ");
            assert_eq!(
                cold.total_energy_uj.to_bits(),
                run.total_energy_uj.to_bits(),
                "{name}: energy differs"
            );
            assert_eq!(cold.layers.len(), run.layers.len());
            for (l0, l1) in cold.layers.iter().zip(&run.layers) {
                assert_eq!(
                    (l0.class, l0.fmt, l0.count, l0.wall_cycles, l0.total_cycles),
                    (l1.class, l1.fmt, l1.count, l1.wall_cycles, l1.total_cycles),
                    "{name}: per-layer runs differ"
                );
                assert_eq!(l0.energy_uj.to_bits(), l1.energy_uj.to_bits(), "{name}");
            }
        }
    }
}
