//! Cross-layer integration tests: the AOT artifacts produced by the
//! JAX/Pallas pipeline (`make artifacts`) executed through the PJRT
//! runtime, validated against the Rust format library.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not
//! been built — `make artifacts` is a build-time step, and CI runs it
//! before `cargo test`.

use mxdotp::formats::{dot, ElemFormat};
use mxdotp::rng::XorShift;
use mxdotp::runtime::{parse_manifest, Runtime};
use mxdotp::workload::{generate_input, generate_params, DeitConfig};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new("artifacts");
    if !Runtime::artifacts_present(dir) {
        eprintln!("skipping PJRT integration test: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT CPU client"))
}

#[test]
fn fp32_matmul_artifact_matches_reference() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("fp32_matmul.hlo.txt").unwrap();
    let (m, k, n) = (64usize, 256, 64);
    let mut rng = XorShift::new(11);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let out = exe
        .run_f32(&[(&a, &[m as i64, k as i64]), (&b, &[k as i64, n as i64])])
        .unwrap();
    let want = dot::matmul_f32(&a, &b, m, k, n);
    for (i, (&g, &w)) in out[0].iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "C[{i}]: {g} vs {w}");
    }
}

#[test]
fn mx_matmul_artifacts_match_rust_quantized_reference() {
    let Some(rt) = runtime() else { return };
    for (file, fmt) in [
        ("mx_matmul_e4m3.hlo.txt", ElemFormat::E4M3),
        ("mx_matmul_e5m2.hlo.txt", ElemFormat::E5M2),
    ] {
        let exe = rt.load(file).unwrap();
        let (m, k, n) = (64usize, 256, 64);
        let mut rng = XorShift::new(13);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let out = exe
            .run_f32(&[(&a, &[m as i64, k as i64]), (&b, &[k as i64, n as i64])])
            .unwrap();
        // The Pallas kernel (Layer 1) and the Rust reference perform
        // the same quantization and the same block-scaled products;
        // accumulation orders differ, so compare to FP32 round-off.
        let want = dot::quantize_matmul_ref(&a, &b, m, k, n, fmt, 32);
        let mut max_rel: f64 = 0.0;
        for (&g, &w) in out[0].iter().zip(&want) {
            let rel = ((g - w).abs() / w.abs().max(1e-3)) as f64;
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 1e-4, "{file}: max rel dev {max_rel}");
    }
}

#[test]
fn deit_block_artifact_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let cfg = DeitConfig::default();
    let params = generate_params(&cfg, 42);
    let x = generate_input(&cfg, 7);
    let exe = rt.load("model.hlo.txt").unwrap();
    let mut inputs: Vec<(&[f32], Vec<i64>)> =
        vec![(&x, vec![cfg.seq as i64, cfg.dim as i64])];
    for (_, shape, data) in &params {
        inputs.push((data, shape.iter().map(|&d| d as i64).collect()));
    }
    let refs: Vec<(&[f32], &[i64])> = inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
    let out = exe.run_f32(&refs).unwrap();
    assert_eq!(out[0].len(), cfg.seq * cfg.dim);
    assert!(out[0].iter().all(|v| v.is_finite()));
    // residual architecture: output should correlate with the input
    let dot: f64 = out[0].iter().zip(&x).map(|(&o, &i)| (o * i) as f64).sum();
    assert!(dot > 0.0, "residual path missing?");
}

#[test]
fn manifest_lists_all_artifacts() {
    let Some(rt) = runtime() else { return };
    let text = std::fs::read_to_string(rt.artifact_dir.join("manifest.txt")).unwrap();
    let entries = parse_manifest(&text);
    let files: Vec<&str> = entries.iter().map(|e| e.file.as_str()).collect();
    for f in [
        "model.hlo.txt",
        "mx_matmul_e4m3.hlo.txt",
        "mx_matmul_e5m2.hlo.txt",
        "fp32_matmul.hlo.txt",
    ] {
        assert!(files.contains(&f), "{f} missing from manifest");
        assert!(rt.artifact_dir.join(f).exists(), "{f} missing on disk");
    }
}

#[test]
fn coordinator_end_to_end_with_pjrt() {
    use mxdotp::coordinator::{BatchPolicy, Coordinator, PjrtExecutor, Request};
    let Some(rt) = runtime() else { return };
    let cfg = DeitConfig::default();
    let params = generate_params(&cfg, 42);
    let exec = PjrtExecutor::new(&rt, cfg, params).unwrap();
    let mut coord = Coordinator::new(cfg, BatchPolicy { max_batch: 4, max_wait_ticks: 2 }, exec, 0.75);
    for i in 0..6 {
        coord.submit(Request { id: i, input: generate_input(&cfg, 100 + i) });
    }
    let mut responses = Vec::new();
    while coord.pending() > 0 {
        responses.extend(coord.tick().expect("tick"));
    }
    assert_eq!(responses.len(), 6);
    assert!(responses.iter().all(|r| r.output.iter().all(|v| v.is_finite())));
    assert!(coord.stats.total_sim_energy_uj > 0.0);
}
