//! Mixed-precision model-graph acceptance tests (DESIGN.md §13).
//!
//! Two invariants guard the graph-executor refactor:
//!
//! 1. **No silent drift**: the `all-fp8` preset (and every uniform
//!    policy) must reproduce the pre-refactor single-format
//!    `ShardedExecutor` recipe *bit for bit*. The reference below is a
//!    frozen copy of that recipe (as it stood before the refactor),
//!    deliberately duplicated here so a behavioral change in the
//!    library cannot silently rewrite its own oracle.
//! 2. **Placement never changes results**: for *any* precision
//!    policy, sequential, batched, and concurrent (disjoint-fabric)
//!    execution produce bit-identical outputs; and each policy GEMM
//!    layer is bit-identical between the single-cluster, sharded, and
//!    leased-concurrent cycle-accurate paths.

use mxdotp::coordinator::ShardedExecutor;
use mxdotp::formats::{dot, ElemFormat, MxMatrix, ScaleAxis};
use mxdotp::kernels::{run_mm, KernelKind};
use mxdotp::model::{
    GraphExecutor, LayerClass, LayerPrecision, ModelGraph, PrecisionPolicy,
};
use mxdotp::rng::{property_cases, XorShift};
use mxdotp::scaleout::{sharded_mm, sharded_mm_leased, FabricLease, ScaleoutConfig};
use mxdotp::workload::{generate_input, generate_params, DeitConfig};

// --------------------------------------------------------------------
// Frozen pre-refactor reference (the seed ShardedExecutor recipe)
// --------------------------------------------------------------------

/// The single-format DeiT encoder block exactly as the pre-refactor
/// `ShardedExecutor::forward_block` computed it: four MX-quantized
/// linears at `cfg.fmt` (weights col-axis, activations row-axis,
/// FP32 bias add), FP32 LayerNorm / fused attention / GELU /
/// residuals.
fn legacy_forward_block(
    cfg: &DeitConfig,
    params: &[(String, Vec<usize>, Vec<f32>)],
    x: &[f32],
) -> Vec<f32> {
    let param = |name: &str| -> &[f32] {
        &params.iter().find(|(n, _, _)| n == name).expect("param").2
    };
    let mx_linear = |x: &[f32], w_name: &str, b: &[f32], m: usize, k: usize, n: usize| {
        let qx = MxMatrix::quantize(x, m, k, cfg.fmt, cfg.block_size, ScaleAxis::Row);
        let qw = MxMatrix::quantize(param(w_name), k, n, cfg.fmt, cfg.block_size, ScaleAxis::Col);
        let mut y = dot::matmul_ref(&qx, &qw);
        for row in y.chunks_mut(n) {
            for (v, &bc) in row.iter_mut().zip(b) {
                *v += bc;
            }
        }
        y
    };
    let layer_norm = |x: &[f32], gamma: &[f32], beta: &[f32]| {
        let d = cfg.dim;
        let mut out = vec![0.0f32; x.len()];
        for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let r = 1.0 / (var + 1e-6).sqrt();
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = (v - mu) * r;
            }
            for (c, o) in orow.iter_mut().enumerate() {
                *o = *o * gamma[c] + beta[c];
            }
        }
        out
    };
    let gelu = |x: f32| {
        const C: f32 = 0.797_884_6;
        0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
    };

    let (s, d) = (cfg.seq, cfg.dim);
    let h = cfg.heads;
    let hd = d / h;
    let md = cfg.mlp_dim();

    let y = layer_norm(x, param("ln1_gamma"), param("ln1_beta"));
    let qkv = mx_linear(&y, "w_qkv", param("b_qkv"), s, d, 3 * d);
    let at = |t: usize, which: usize, head: usize, e: usize| {
        qkv[t * 3 * d + which * d + head * hd + e]
    };
    let mut ctx = vec![0.0f32; s * d];
    let mut scores = vec![0.0f32; s];
    for head in 0..h {
        for tq in 0..s {
            let mut max = f32::NEG_INFINITY;
            for (tk, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for e in 0..hd {
                    acc += at(tq, 0, head, e) * at(tk, 1, head, e);
                }
                *sc = acc / (hd as f32).sqrt();
                max = max.max(*sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                denom += *sc;
            }
            for e in 0..hd {
                let mut acc = 0.0f32;
                for (tk, &sc) in scores.iter().enumerate() {
                    acc += sc * at(tk, 2, head, e);
                }
                ctx[tq * d + head * hd + e] = acc / denom;
            }
        }
    }
    let proj = mx_linear(&ctx, "w_proj", param("b_proj"), s, d, d);
    let x1: Vec<f32> = x.iter().zip(&proj).map(|(&a, &b)| a + b).collect();

    let y = layer_norm(&x1, param("ln2_gamma"), param("ln2_beta"));
    let mut hval = mx_linear(&y, "w_fc1", param("b_fc1"), s, d, md);
    for v in hval.iter_mut() {
        *v = gelu(*v);
    }
    let out = mx_linear(&hval, "w_fc2", param("b_fc2"), s, md, d);
    x1.iter().zip(&out).map(|(&a, &b)| a + b).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: element {i} ({g} vs {w})");
    }
}

// --------------------------------------------------------------------
// 1. all-fp8 (and every uniform policy) == the pre-refactor path
// --------------------------------------------------------------------

#[test]
fn uniform_policies_bit_match_the_frozen_pre_refactor_recipe() {
    for fmt in [ElemFormat::E4M3, ElemFormat::E5M2, ElemFormat::E2M1, ElemFormat::Int8] {
        let cfg = DeitConfig { seq: 8, fmt, ..DeitConfig::default() };
        let params = generate_params(&cfg, 11);
        let exec = GraphExecutor::new(cfg, PrecisionPolicy::uniform(fmt), params.clone())
            .unwrap();
        for seed in [3u64, 7] {
            let x = generate_input(&cfg, seed);
            let want = legacy_forward_block(&cfg, &params, &x);
            let got = exec.forward_ref(&x).unwrap();
            assert_bits_eq(&got, &want, &format!("uniform({fmt}), input {seed}"));
        }
    }
}

#[test]
fn all_fp8_preset_is_the_pre_refactor_default_path() {
    // The acceptance criterion verbatim: the `all-fp8` preset on the
    // default DeiT config reproduces the pre-refactor single-format
    // path bit for bit — through the GraphExecutor AND through the
    // ShardedExecutor wrapper the serving stack uses.
    let cfg = DeitConfig { seq: 8, ..DeitConfig::default() };
    assert_eq!(cfg.fmt, ElemFormat::E4M3, "the default format is FP8 E4M3");
    let params = generate_params(&cfg, 42);
    let x = generate_input(&cfg, 5);
    let want = legacy_forward_block(&cfg, &params, &x);
    let graph =
        GraphExecutor::new(cfg, PrecisionPolicy::preset("all-fp8").unwrap(), params.clone())
            .unwrap();
    assert_bits_eq(&graph.forward_ref(&x).unwrap(), &want, "all-fp8 GraphExecutor");
    let wrapper = ShardedExecutor::new(cfg, params);
    assert_bits_eq(&wrapper.forward_ref(&x).unwrap(), &want, "ShardedExecutor wrapper");
}

// --------------------------------------------------------------------
// 2. any policy: sequential == batched == concurrent (bit-identical)
// --------------------------------------------------------------------

#[test]
fn any_policy_is_pure_across_sequential_batched_and_concurrent_execution() {
    // Random policies (random per-class formats, occasionally FP32
    // layers) over random inputs: the three execution disciplines must
    // agree bit for bit. seq 8 keeps attention FP32-only policies
    // cheap; a separate case below covers MX attention at seq 64.
    let cfg = DeitConfig { seq: 8, ..DeitConfig::default() };
    let params = generate_params(&cfg, 23);
    property_cases(6, 0x90CF, |rng: &mut XorShift| {
        let mut policy = PrecisionPolicy::uniform(cfg.fmt);
        for class in [LayerClass::Qkv, LayerClass::AttnOut, LayerClass::MlpUp, LayerClass::MlpDown]
        {
            let prec = match rng.below(7) {
                6 => LayerPrecision::Fp32,
                i => LayerPrecision::Mx(ElemFormat::ALL[i as usize]),
            };
            policy.set(class, prec);
        }
        let exec = GraphExecutor::new(cfg, policy, params.clone()).unwrap();
        let base = 100 + rng.below(50);
        let inputs: Vec<Vec<f32>> =
            (0..4u64).map(|i| generate_input(&cfg, base + i)).collect();
        // sequential
        let seq: Vec<Vec<f32>> =
            inputs.iter().map(|x| exec.forward_ref(x).unwrap()).collect();
        // concurrent on two disjoint "fabrics"
        let batches = vec![inputs[..2].to_vec(), inputs[2..].to_vec()];
        let conc = exec.forward_concurrent(&batches);
        for (i, (want, got)) in
            seq.iter().zip(conc.iter().flatten()).enumerate()
        {
            assert_bits_eq(got, want, &format!("policy {policy}, input {i}"));
        }
    });
}

#[test]
fn mx_attention_policy_is_pure_across_execution_disciplines() {
    // seq 64 divides the block size, so the attention GEMMs themselves
    // can be MX-quantized; purity must hold for them too.
    let cfg = DeitConfig { seq: 64, ..DeitConfig::default() };
    let params = generate_params(&cfg, 29);
    let policy = PrecisionPolicy::parse(
        "attn=e4m3,ffn=fp4",
        PrecisionPolicy::uniform(cfg.fmt),
    )
    .unwrap();
    let exec = GraphExecutor::new(cfg, policy, params).unwrap();
    let inputs: Vec<Vec<f32>> = (0..2u64).map(|i| generate_input(&cfg, 700 + i)).collect();
    let seq: Vec<Vec<f32>> = inputs.iter().map(|x| exec.forward_ref(x).unwrap()).collect();
    let conc = exec.forward_concurrent(&[vec![inputs[0].clone()], vec![inputs[1].clone()]]);
    for (want, got) in seq.iter().zip(conc.iter().flatten()) {
        assert_bits_eq(got, want, "mx-attention policy");
    }
}

// --------------------------------------------------------------------
// 3. per-layer GEMMs: sequential == sharded == leased-concurrent
// --------------------------------------------------------------------

#[test]
fn policy_layers_bit_identical_across_sequential_sharded_and_leased_paths() {
    // Every MX GEMM layer of the fp4-ffn policy (mixed formats!), on a
    // reduced sequence: the single-cluster run, the 2-cluster sharded
    // run, and a leased run at a nonzero machine offset must produce
    // bit-identical C matrices.
    let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
    let graph = ModelGraph::deit_block(&cfg);
    let policy = PrecisionPolicy::preset("fp4-ffn").unwrap();
    for (class, p, _) in graph.mx_problems(&policy) {
        let mut rng = XorShift::new(0x1A7E ^ class.index() as u64);
        let a = rng.normal_vec(p.m * p.k, 0.5);
        let b = rng.normal_vec(p.k * p.n, 0.02);
        let single = run_mm(KernelKind::Mx(p.fmt), p, &a, &b, 8);
        let sharded = sharded_mm(&ScaleoutConfig::with_clusters(2), p, &a, &b);
        let lease = FabricLease { first_cluster: 4, clusters: 2 };
        let leased = sharded_mm_leased(&ScaleoutConfig::with_clusters(2), lease, p, &a, &b);
        assert_bits_eq(&sharded.c, &single.c, &format!("{class}: sharded vs sequential"));
        assert_bits_eq(&leased.c, &sharded.c, &format!("{class}: leased vs sharded"));
        assert_eq!(leased.wall_cycles, sharded.wall_cycles, "{class}: lease changed timing");
    }
}

// --------------------------------------------------------------------
// 4. the fp4-ffn hardware walk beats all-fp8 (reduced shapes)
// --------------------------------------------------------------------

#[test]
fn fp4_ffn_hw_walk_is_faster_than_all_fp8_at_equal_flops() {
    let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
    let graph = ModelGraph::deit_block(&cfg);
    let fp8 = PrecisionPolicy::preset("all-fp8").unwrap();
    let ffn4 = PrecisionPolicy::preset("fp4-ffn").unwrap();
    let r8 = mxdotp::model::policy_hw_run(&graph, &fp8, 2, 8, 3, false, 1);
    let r4 = mxdotp::model::policy_hw_run(&graph, &ffn4, 2, 8, 3, false, 1);
    assert_eq!(r8.flops, r4.flops);
    let ratio = r8.wall_cycles as f64 / r4.wall_cycles as f64;
    assert!(ratio >= 1.2, "fp4-ffn wall speedup only {ratio:.2}x on reduced shapes");
    assert_eq!(r8.csr_switches, 1);
    assert_eq!(r4.csr_switches, 2);
}
