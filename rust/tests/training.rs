//! Training workload integration tests (DESIGN.md §18): the
//! backward-pass dX/dW GEMMs must be **bit-identical** however the
//! fabric executes them — sequentially on one cluster, sharded across
//! a cluster fabric, or concurrently on disjoint fabric leases.
//! RNE quantization and the `mxdotp` accumulation chain are
//! deterministic and row-sharding never reorders an accumulation, so
//! the execution strategy must be invisible in the bits.

use mxdotp::model::{BackwardKind, LayerClass, ModelGraph, PrecisionPolicy};
use mxdotp::rng::XorShift;
use mxdotp::scaleout::{sharded_mm, sharded_mm_leased, FabricLease, ScaleoutConfig};
use mxdotp::workload::DeitConfig;

/// Small graph whose every forward/backward GEMM keeps K a multiple
/// of the MX block (seq 32, dim 96 → K ∈ {32, 96, 192, 288}).
fn graph() -> ModelGraph {
    let cfg = DeitConfig { seq: 32, dim: 96, mlp_ratio: 2, ..DeitConfig::default() };
    ModelGraph::deit_block(&cfg)
}

/// Deterministic operands for one backward GEMM.
fn operands(
    class: LayerClass,
    kind: BackwardKind,
    m: usize,
    k: usize,
    n: usize,
) -> (Vec<f32>, Vec<f32>) {
    let tag = match kind {
        BackwardKind::Dx => 1u64,
        BackwardKind::Dw => 2u64,
    };
    let mut rng = XorShift::new(0xBAC4 ^ ((class.index() as u64 + 1) << 32) ^ (tag << 48));
    (rng.normal_vec(m * k, 0.5), rng.normal_vec(k * n, 0.02))
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: C[{i}] = {g:?} ({:#010x}) vs {w:?} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// The satellite invariant: every backward GEMM of the all-fp8 policy
/// produces the same bits on 1 cluster, sharded across 2 and 4
/// clusters, and on a 2-cluster fabric lease carved out of a larger
/// machine.
#[test]
fn backward_gemms_bit_identical_across_execution_strategies() {
    let graph = graph();
    let policy = PrecisionPolicy::preset("all-fp8").expect("preset");
    let problems = graph.mx_backward_problems(&policy);
    assert!(!problems.is_empty(), "all-fp8 must quantize backward GEMMs");
    for &(class, kind, p, _count) in &problems {
        let (a, b) = operands(class, kind, p.m, p.k, p.n);
        let want = sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b);
        for clusters in [2usize, 4] {
            let got = sharded_mm(&ScaleoutConfig::with_clusters(clusters), p, &a, &b);
            assert_bits_eq(
                &got.c,
                &want.c,
                &format!("{class:?}/{kind} on {clusters} clusters"),
            );
        }
        // a lease in the middle of a 4-cluster machine: shard math must
        // not depend on machine-global cluster ids
        let leased = sharded_mm_leased(
            &ScaleoutConfig::with_clusters(4),
            FabricLease { first_cluster: 2, clusters: 2 },
            p,
            &a,
            &b,
        );
        assert_bits_eq(&leased.c, &want.c, &format!("{class:?}/{kind} on a lease"));
    }
}

/// Disjoint leases running *concurrently* (host threads, like the
/// serving engine's continuous scheduler) must not perturb results:
/// each thread's outputs match the sequential single-cluster bits.
#[test]
fn backward_gemms_bit_identical_under_concurrent_disjoint_leases() {
    let graph = graph();
    let policy = PrecisionPolicy::preset("all-fp8").expect("preset");
    let problems = graph.mx_backward_problems(&policy);
    let sequential: Vec<Vec<f32>> = problems
        .iter()
        .map(|&(class, kind, p, _)| {
            let (a, b) = operands(class, kind, p.m, p.k, p.n);
            sharded_mm(&ScaleoutConfig::with_clusters(1), p, &a, &b).c
        })
        .collect();
    // two disjoint 2-cluster leases on one 4-cluster machine, each
    // draining half of the backward problem list concurrently
    let leases = [
        FabricLease { first_cluster: 0, clusters: 2 },
        FabricLease { first_cluster: 2, clusters: 2 },
    ];
    assert!(leases[0].is_disjoint(&leases[1]));
    let concurrent: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = leases
            .iter()
            .enumerate()
            .map(|(li, &lease)| {
                let problems = &problems;
                s.spawn(move || {
                    problems
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % 2 == li)
                        .map(|(i, &(class, kind, p, _))| {
                            let (a, b) = operands(class, kind, p.m, p.k, p.n);
                            let run = sharded_mm_leased(
                                &ScaleoutConfig::with_clusters(4),
                                lease,
                                p,
                                &a,
                                &b,
                            );
                            (i, run.c)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("lease thread")).collect()
    });
    assert_eq!(concurrent.len(), problems.len());
    for (i, c) in concurrent {
        assert_bits_eq(&c, &sequential[i], &format!("concurrent lease, problem {i}"));
    }
}
