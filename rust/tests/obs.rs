//! Observability acceptance tests (DESIGN.md §14).
//!
//! The layer's two load-bearing properties are asserted end to end:
//!
//! 1. **Reconciliation** — derived spans are the scheduler's own
//!    accounting re-expressed on a timeline: per-fabric serve-span
//!    durations sum to the outcome's busy ticks exactly, per-cluster
//!    scale-out spans to the cluster's cycle count, per-layer policy
//!    spans tile the run's wall clock with no gaps.
//! 2. **Determinism / non-interference** — artifacts are byte-stable
//!    across independent reruns (they carry only simulated time), and
//!    enabling tracing changes no simulated number (the one traced
//!    execution path, the scale-out pool, is bit-identical with and
//!    without a sink).

use mxdotp::formats::ElemFormat;
use mxdotp::kernels::MmProblem;
use mxdotp::model::{policy_hw_run, ModelGraph, PrecisionPolicy};
use mxdotp::obs::{self, perfetto, TraceSink};
use mxdotp::rng::XorShift;
use mxdotp::scaleout::{sharded_mm, sharded_mm_traced, ScaleoutConfig};
use mxdotp::serve::{self, scheduler::ServeOutcome, CostModel, SchedulerKind, ServeConfig};
use mxdotp::workload::arrivals::{generate_trace, ArrivalKind, ArrivalSpec};
use mxdotp::workload::DeitConfig;

/// One canonical serving run: mixed formats, mixed priorities, bursty
/// arrivals at a rate that forces queueing on a 4-cluster machine.
fn serve_outcome(kind: SchedulerKind) -> (ServeOutcome, ServeConfig) {
    let cfg = ServeConfig { clusters: 4, scheduler: kind, ..ServeConfig::default() };
    let spec = ArrivalSpec {
        kind: ArrivalKind::Bursty { burst_factor: 4.0, period_ticks: 2000 },
        rate_per_ktick: serve::estimated_capacity_per_ktick(
            &cfg,
            &[(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)],
        ),
        mix: vec![(ElemFormat::E4M3, 0.6), (ElemFormat::E2M1, 0.4)],
        high_priority_frac: 0.25,
        requests: 120,
        seed: 9,
    };
    let outcome = serve::simulate(&cfg, &generate_trace(&spec));
    (outcome, cfg)
}

#[test]
fn serve_span_durations_reconcile_with_busy_ticks_per_fabric() {
    for kind in [SchedulerKind::Continuous, SchedulerKind::Barrier] {
        let (outcome, cfg) = serve_outcome(kind);
        assert!(!outcome.served.is_empty(), "{kind:?}: nothing served");
        let sink = obs::serve_spans(&outcome, &CostModel::build(&cfg));
        for (f, &busy) in outcome.fabric_busy_ticks.iter().enumerate() {
            assert_eq!(
                sink.track_total_ns(obs::PID_SERVE, f as u32),
                obs::ticks_to_ns(busy),
                "{kind:?}: fabric {f} span sum must equal its busy ticks"
            );
        }
    }
}

#[test]
fn trace_and_metrics_artifacts_are_byte_identical_across_reruns() {
    // Two fully independent pipelines (trace generation, simulation,
    // span derivation, rendering) — the same property CI's determinism
    // job checks on the OBS_* files, here without the filesystem.
    let render = || {
        let (outcome, cfg) = serve_outcome(SchedulerKind::Continuous);
        let trace = perfetto::render(&obs::serve_spans(&outcome, &CostModel::build(&cfg)));
        let metrics = obs::serve_metrics(&outcome).render_json();
        (trace, metrics)
    };
    let (t1, m1) = render();
    let (t2, m2) = render();
    assert_eq!(t1, t2, "Perfetto trace must be byte-identical across reruns");
    assert_eq!(m1, m2, "metrics JSON must be byte-identical across reruns");
    // sim-only artifacts carry no host keys at all
    assert!(!t1.contains("host_"), "trace must not carry host keys");
    assert!(!m1.contains("host_"), "sim-only metrics must not carry host keys");
    // the registry's host block is quarantined under the host_ prefix
    // (the convention tools/check_determinism.py strips by)
    let with_host =
        obs::Registry::new().render_json_with_host(Some(&obs::hostprof::snapshot()));
    assert!(with_host.contains("\"host_sim_wall_ms\""), "{with_host}");
    assert!(with_host.contains("\"host_plan_builds\""), "{with_host}");
}

#[test]
fn scaleout_tracing_on_and_off_is_bit_identical() {
    let p = MmProblem { m: 48, k: 256, n: 64, fmt: ElemFormat::E4M3, block_size: 32 };
    let mut rng = XorShift::new(17);
    let a = rng.normal_vec(p.m * p.k, 1.0);
    let b = rng.normal_vec(p.k * p.n, 1.0);
    let cfg = ScaleoutConfig::with_clusters(4);
    let plain = sharded_mm(&cfg, p, &a, &b);
    let mut sink = TraceSink::new();
    let traced = sharded_mm_traced(&cfg, p, &a, &b, &mut sink);
    for (i, (x, y)) in plain.c.iter().zip(&traced.c).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "C[{i}] differs with tracing on");
    }
    assert_eq!(traced.wall_cycles, plain.wall_cycles);
    assert_eq!(traced.total_cycles, plain.total_cycles);
    assert_eq!(traced.total_mxdotp, plain.total_mxdotp);
    assert_eq!(traced.total_energy_uj.to_bits(), plain.total_energy_uj.to_bits());
    // the trace it recorded reconciles with the per-cluster stats
    assert_eq!(sink.spans().len(), traced.shards, "one span per shard");
    for st in &traced.clusters {
        assert_eq!(
            sink.track_total_ns(obs::PID_CLUSTERS, st.id as u32),
            st.cycles,
            "cluster {} span sum must equal its cycles",
            st.id
        );
    }
}

#[test]
fn policy_layer_spans_tile_the_wall_clock_exactly() {
    let cfg = DeitConfig { seq: 16, ..DeitConfig::default() };
    let graph = ModelGraph::deit_block(&cfg);
    let policy = PrecisionPolicy::preset("fp4-ffn").unwrap();
    let run = policy_hw_run(&graph, &policy, 2, 8, 5, false, 1);
    let sink = obs::policy_spans(&run);
    let layer_spans: Vec<_> =
        sink.spans().iter().filter(|s| s.tid == 0 && s.pid == obs::PID_MODEL).collect();
    assert_eq!(layer_spans.len(), run.layers.len());
    // back-to-back: each layer starts where the previous one ended,
    // and together they cover [0, wall_cycles) without gaps
    let mut at = 0u64;
    for s in &layer_spans {
        assert_eq!(s.ts_ns, at, "layer span '{}' must start at the running wall", s.name);
        at += s.dur_ns;
    }
    assert_eq!(at, run.wall_cycles, "layer spans must tile the wall clock");
    // CSR markers are instantaneous and at least the initial format set
    let markers: Vec<_> = sink.spans().iter().filter(|s| s.tid == 1).collect();
    assert!(!markers.is_empty());
    assert!(markers.iter().all(|m| m.dur_ns == 0 && m.cat == "model.csr"));
    // the metrics rollup agrees with the run's own accounting
    let reg = obs::policy_metrics(&run);
    assert_eq!(reg.counter("model.wall_cycles"), run.wall_cycles);
    assert_eq!(reg.counter("model.flops"), run.flops);
    assert_eq!(reg.counter("model.csr_switches"), run.csr_switches as u64);
}

#[test]
fn serve_trace_passes_the_schema_rules_check_trace_enforces() {
    // The same structural rules tools/check_trace.py enforces in CI,
    // asserted on the rendered JSON text: array form, per-line events,
    // and per-track monotonic timestamps in emission order.
    let (outcome, cfg) = serve_outcome(SchedulerKind::Continuous);
    let sink = obs::serve_spans(&outcome, &CostModel::build(&cfg));
    let json = perfetto::render(&sink);
    assert!(json.starts_with("[\n") && json.ends_with("\n]\n"), "must be a JSON array");
    assert!(json.contains("\"ph\":\"M\"") && json.contains("\"process_name\""));
    assert!(json.contains("\"ph\":\"X\"") && json.contains("\"dur\":"));
    assert!(json.contains("\"ph\":\"C\"") && json.contains("\"queued requests\""));
    let sorted = perfetto::sorted_spans(&sink);
    for w in sorted.windows(2) {
        if (w[0].pid, w[0].tid) == (w[1].pid, w[1].tid) {
            assert!(w[0].ts_ns <= w[1].ts_ns, "ts must be monotonic per track");
        }
    }
    // every span ends within the simulated horizon
    for s in sink.spans() {
        assert!(
            s.ts_ns + s.dur_ns <= obs::ticks_to_ns(outcome.horizon_ticks),
            "span '{}' runs past the horizon",
            s.name
        );
    }
}

#[test]
fn hostprof_records_real_simulator_activity() {
    let before = obs::hostprof::snapshot();
    let p = MmProblem { m: 16, k: 64, n: 16, fmt: ElemFormat::E4M3, block_size: 32 };
    let mut rng = XorShift::new(3);
    let a = rng.normal_vec(p.m * p.k, 1.0);
    let b = rng.normal_vec(p.k * p.n, 1.0);
    let run = mxdotp::kernels::run_mm(mxdotp::kernels::KernelKind::Mx(p.fmt), p, &a, &b, 8);
    let after = obs::hostprof::snapshot();
    // deltas, not absolutes: other tests in this binary also simulate
    assert!(after.sim_runs > before.sim_runs, "cluster run must be profiled");
    assert!(after.sim_cycles >= before.sim_cycles + run.perf.cycles);
    assert!(after.sim_wall_nanos > before.sim_wall_nanos);
}
