//! Integration tests for the VMXDOTP vector datapath (DESIGN.md §16):
//! the vector kernel must be bit-identical to the scalar `mxdotp`
//! kernel (which is itself pinned to `reference::mx_hw_ref`) for every
//! element format, block size and vector length, wall cycles must be
//! monotone in VL on deep-reduction shapes, the simulator fast path
//! must be invisible to vector kernels, and `--vector-len 1` must be
//! bit- AND cycle-identical to the scalar path.

use mxdotp::formats::ElemFormat;
use mxdotp::kernels::plan::{run_mm_cached, PlanCache};
use mxdotp::kernels::reference::mx_hw_ref;
use mxdotp::kernels::{run_mm, KernelKind, MmProblem, MmRun};
use mxdotp::rng::{property_cases, XorShift};
use mxdotp::snitch::{Cluster, ClusterConfig};

/// Vector lengths the vector unit supports beyond the scalar VL = 1.
const VLS: [u8; 3] = [2, 4, 8];

/// Bit-compare two C matrices; NaN is compared as "both NaN" so
/// NaN-propagating cases stay assertable (quantized NaNs all carry the
/// format's canonical encoding, so cross-run bits still match).
fn assert_c_bits(what: &str, want: &[f32], got: &[f32]) {
    assert_eq!(want.len(), got.len(), "{what}: result shape differs");
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert!(
            w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()),
            "{what}: C[{i}] differs ({w} vs {g})"
        );
    }
}

/// Operand vector with the hostile cases the datapath must normalize
/// deterministically: a sprinkle of NaN / ±Inf inputs and runs of
/// subnormal-heavy values (tiny magnitudes force subnormal element
/// encodings once the block scale normalizes the in-block amax).
fn hostile_vec(rng: &mut XorShift, n: usize, std: f32) -> Vec<f32> {
    let mut v = rng.normal_vec(n, std);
    for x in v.iter_mut() {
        match rng.below(16) {
            0 => *x = f32::NAN,
            1 => *x = f32::INFINITY,
            2 => *x = f32::NEG_INFINITY,
            3..=6 => *x *= 1e-40, // deep into f32 subnormal territory
            _ => {}
        }
    }
    v
}

#[test]
fn vector_is_bit_identical_to_scalar_across_formats() {
    // Random block-aligned shapes × all six formats × VL ∈ {2,4,8},
    // with NaN/Inf and subnormal-heavy operands: the vector unit chains
    // VL blocks through the scalar datapath in ascending block order,
    // so identity with the scalar kernel (and with the shared hardware
    // reference) is exact, not approximate.
    property_cases(8, 0x7EC7_0001, |rng| {
        let fmt = ElemFormat::ALL[rng.below(ElemFormat::ALL.len() as u64) as usize];
        let p = MmProblem {
            m: 8 * (1 + rng.below(2) as usize),
            k: 64 * (1 + rng.below(3) as usize),
            n: 8 * (1 + rng.below(2) as usize),
            fmt,
            block_size: 32,
        };
        let a = hostile_vec(rng, p.m * p.k, 0.5);
        let b = hostile_vec(rng, p.k * p.n, 0.02);
        let scalar = run_mm(KernelKind::Mx(fmt), p, &a, &b, 2);
        let want = mx_hw_ref(&p, &a, &b);
        assert_c_bits(&format!("{fmt} scalar vs hw ref"), &want, &scalar.c);
        for &vl in &VLS {
            let vec = run_mm(p.vmx_kernel(vl), p, &a, &b, 2);
            assert!(
                vec.perf.vmxdotp_total() > 0,
                "{fmt} vl={vl}: no vmxdotp issued"
            );
            assert_c_bits(&format!("{fmt} vl={vl} vs scalar"), &scalar.c, &vec.c);
        }
    });
}

#[test]
fn vector_handles_block_sizes_16_and_64() {
    // "the block size remains configurable in software": the vector
    // unit's per-group word count (1 + VL·bw) tracks the block size, so
    // one FP4 issue per block (bs = 16, 16 lanes) through the widest
    // group (bs = 64, VL = 8, 8 lanes: the 65-word ceiling) must all
    // stay bit-identical to the scalar kernel.
    for fmt in ElemFormat::ALL {
        for bs in [16usize, 64] {
            let p = MmProblem { m: 8, k: 128, n: 8, fmt, block_size: bs };
            let mut rng = XorShift::new(0xB5 ^ bs as u64);
            let a = rng.normal_vec(p.m * p.k, 1.0);
            let b = rng.normal_vec(p.k * p.n, 1.0);
            let scalar = run_mm(KernelKind::Mx(fmt), p, &a, &b, 2);
            for vl in [2u8, 8] {
                let vec = run_mm(p.vmx_kernel(vl), p, &a, &b, 2);
                assert_c_bits(&format!("{fmt} bs={bs} vl={vl}"), &scalar.c, &vec.c);
            }
        }
    }
}

#[test]
fn wall_cycles_are_monotone_in_vl() {
    // On a deep-reduction shape (kb = k/bs = 8 blocks, so even VL = 8
    // needs no tail padding) doubling VL may never cost wall cycles:
    // each doubling halves the scale-header overhead and the per-group
    // issue count. The endpoint must also show real uplift, not a tie.
    for fmt in [ElemFormat::E4M3, ElemFormat::E2M1, ElemFormat::Int8] {
        let p = MmProblem { m: 16, k: 256, n: 16, fmt, block_size: 32 };
        let mut rng = XorShift::new(0x0AB1E5);
        let a = rng.normal_vec(p.m * p.k, 0.5);
        let b = rng.normal_vec(p.k * p.n, 0.02);
        let scalar = run_mm(KernelKind::Mx(fmt), p, &a, &b, 1);
        let mut prev = scalar.perf.cycles;
        for &vl in &VLS {
            let run = run_mm(p.vmx_kernel(vl), p, &a, &b, 1);
            assert!(
                run.perf.cycles <= prev,
                "{fmt}: vl={vl} took {} cycles, more than the previous VL's {prev}",
                run.perf.cycles
            );
            prev = run.perf.cycles;
        }
        assert!(
            (prev as f64) < 0.75 * scalar.perf.cycles as f64,
            "{fmt}: VL=8 ({prev} cycles) shows no uplift over scalar ({})",
            scalar.perf.cycles
        );
    }
}

/// Run one kernel on a fresh single instance with the fast path forced
/// on or off for that instance (the per-instance flag, not the
/// process-wide default — tests in this binary run concurrently).
fn run_with(fast: bool, kind: KernelKind, p: MmProblem, a: &[f32], b: &[f32]) -> MmRun {
    let cache = PlanCache::disabled();
    let mut cl = Cluster::new(ClusterConfig { num_cores: 8, freq_ghz: 1.0 });
    cl.fast_path = fast;
    run_mm_cached(&cache, &mut cl, kind, p, a, b)
}

#[test]
fn fast_path_is_invisible_for_vector_kernels() {
    // The widened FREP fast-forward (DESIGN.md §15) must retire vector
    // FREP bodies — wider SSR groups, multi-cycle vmxdotp occupancy —
    // exactly as per-cycle stepping does: identical counters (cycles,
    // stalls, vmxdotp/mxdotp issue counts) and identical result bits.
    let p = MmProblem { m: 16, k: 128, n: 16, fmt: ElemFormat::E4M3, block_size: 32 };
    let mut rng = XorShift::new(0xFA57_0EC);
    let a = rng.normal_vec(p.m * p.k, 0.5);
    let b = rng.normal_vec(p.k * p.n, 0.02);
    for fmt in [ElemFormat::E4M3, ElemFormat::E2M1] {
        let p = MmProblem { fmt, ..p };
        for &vl in &VLS {
            let kind = p.vmx_kernel(vl);
            let slow = run_with(false, kind, p, &a, &b);
            let fast = run_with(true, kind, p, &a, &b);
            assert_eq!(
                slow.perf, fast.perf,
                "{fmt} vl={vl}: fast path changed the counters"
            );
            assert_c_bits(&format!("{fmt} vl={vl} fast vs slow"), &slow.c, &fast.c);
        }
    }
}

#[test]
fn vl1_is_bit_and_cycle_identical_to_scalar() {
    // Satellite guarantee for `--vector-len 1`: it must normalize to
    // the scalar kernel (one decision point, `MmProblem::vmx_kernel`)
    // and therefore match the scalar path in BOTH bits and counters.
    for fmt in [ElemFormat::E4M3, ElemFormat::E2M1] {
        let p = MmProblem { m: 8, k: 128, n: 8, fmt, block_size: 32 };
        let mut rng = XorShift::new(0x11);
        let a = rng.normal_vec(p.m * p.k, 0.5);
        let b = rng.normal_vec(p.k * p.n, 0.02);
        assert_eq!(p.vmx_kernel(1), KernelKind::Mx(fmt));
        let scalar = run_mm(KernelKind::Mx(fmt), p, &a, &b, 2);
        let vl1 = run_mm(p.vmx_kernel(1), p, &a, &b, 2);
        assert_eq!(scalar.perf, vl1.perf, "{fmt}: VL=1 perturbed the counters");
        assert_c_bits(&format!("{fmt} vl=1 vs scalar"), &scalar.c, &vl1.c);
        assert_eq!(vl1.perf.vmxdotp_total(), 0, "{fmt}: VL=1 issued vmxdotp");
    }
}
